//! The multi-process TCP backend: one machine per OS process, framed
//! sockets instead of in-process channels.
//!
//! ### Topology
//!
//! * **Control plane, hub-and-spoke**: rank 0 (the coordinator) listens on
//!   `transport_addr`; every follower keeps one control socket to it.  The
//!   handshake, the distributed barrier rounds
//!   ([`crate::worker::sync::BarrierLink`]), and the `JobAbort` latch's
//!   remote trips all travel here.
//! * **Data plane, full mesh**: every rank binds an ephemeral listener and
//!   advertises it through the handshake; rank *i* initiates to every rank
//!   *j < i* and accepts from every *j > i*, giving exactly one full-duplex
//!   socket per machine pair.  Each side runs a writer thread (drains the
//!   same `mpsc` queue a sim receiver would, frames each
//!   [`super::Batch`] onto the wire, recycles the sent `BufPool` block)
//!   and a reader thread (reads frames into recycled pool blocks and
//!   feeds the machine's [`super::NetReceiver`] queue) — so
//!   `worker/units.rs` runs bit-for-bit the same code as under sim.
//!
//! ### Handshake
//!
//! Followers connect and send [`FrameKind::Hello`] (`src` = rank, `step` =
//! attempt number, payload = local resume proposal + data address).  The
//! leader collects `n−1` distinct ranks (frames from other attempts are
//! dropped — retry lockstep), computes the **agreed resume point** (the
//! minimum of all proposals, or none if any machine has no usable
//! checkpoint — min is safe because earlier checkpoints are retained), and
//! replies [`FrameKind::Roster`] with the agreement plus every rank's data
//! address.  The whole handshake is bounded by
//! [`TcpOpts::handshake_timeout`]; an absent peer surfaces as a typed
//! [`Error::Io`], not a hang.
//!
//! ### Failure observation (the PR 5 poison flow, across processes)
//!
//! A local trip of the [`JobAbort`] latch reaches this cluster through its
//! [`Poisonable`] registration: the poison hook broadcasts the serialized
//! [`AbortCause`] as a [`FrameKind::Abort`] control frame (followers send
//! to the leader, the leader relays to everyone) and force-closes the data
//! sockets so blocked reads return.  A control reader receiving an Abort
//! frame marks it *remote-origin* **before** tripping the local latch, so
//! the cause crosses each hop once and echo storms are impossible (trips
//! are first-cause-wins and idempotent anyway).  Because the frame carries
//! machine/unit/superstep/cause, every process reports the **originating**
//! failure — `Error::JobFailed` survives the jump from threads to
//! machines, and PR 8's retryable-cause classification stays in lockstep
//! across processes.
//!
//! A peer that dies without tripping anything (SIGKILL) is observed by the
//! OS closing its sockets: EOF *without* a preceding
//! [`FrameKind::Goodbye`] is a death, and the observer trips the latch
//! with a `connection to machine R lost` cause after a short grace period
//! (the grace lets an in-flight Abort frame with the true origin win the
//! first-cause race).  The lost-connection cause deliberately avoids the
//! `"I/O error"` / `"transient"` retryable markers: a vanished peer will
//! not rejoin a retry handshake, so survivors should fail fast rather
//! than burn the retry budget on doomed handshakes.
//!
//! Clean shutdown is the mirror image: senders drain, writers append
//! `Goodbye` and half-close, readers treat post-Goodbye EOF as expected.

use super::frame::{self, FrameKind};
use super::sim::Switch;
use super::{Batch, NetReceiver, NetSender, Payload, ABORT_POLL};
use crate::error::{Error, Result};
use crate::msg::BufPool;
use crate::trace::EventKind;
use crate::worker::sync::{
    lock_clean, wait_timeout_clean, AbortCause, BarrierLink, JobAbort, Poisonable,
};
use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Barrier id of the U_c aggregator/control rendezvous on the wire.
pub const BARRIER_UC: u8 = 1;
/// Barrier id of the U_r transmission-completion rendezvous.
pub const BARRIER_UR: u8 = 2;
/// Barrier id of the checkpoint-durability rendezvous.
pub const BARRIER_CKPT: u8 = 3;

/// Wire sentinel for "no local checkpoint to resume from".
const NO_RESUME: u64 = u64::MAX;

/// How long an EOF-observing reader waits for the *originating* abort
/// cause to arrive on the control plane before synthesizing its own
/// `connection lost` cause.
const LOST_PEER_GRACE: Duration = Duration::from_millis(300);

/// Connection parameters for [`TcpCluster::connect`].
#[derive(Clone, Debug)]
pub struct TcpOpts {
    /// Cluster size (total machine processes).
    pub n: usize,
    /// This process's machine id, `0..n`; rank 0 is the coordinator.
    pub rank: usize,
    /// The coordinator's control-plane address (`host:port`).  Rank 0
    /// binds it (or reuses a listener prebound via [`leader_bind`]);
    /// followers connect to it.
    pub addr: String,
    /// This process's local resume proposal (latest durable checkpoint in
    /// its private checkpoint dir); the handshake agrees on the cluster
    /// minimum.
    pub resume: Option<u64>,
    /// Attempt number (0 = first run, +1 per auto-resume retry).  Tagged
    /// on every handshake frame so stale sockets from a previous attempt
    /// are dropped instead of corrupting the roster.
    pub attempt: u64,
    /// Local-delivery fast path knob, mirroring the sim backend's
    /// (`JobConfig::local_fastpath`).
    pub local_fast: bool,
    /// Bound on the whole handshake (connect + hello + roster + data
    /// mesh).  A peer that never shows up yields a typed [`Error::Io`].
    pub handshake_timeout: Duration,
}

impl TcpOpts {
    /// Options with the default 30 s handshake timeout.
    pub fn new(n: usize, rank: usize, addr: impl Into<String>) -> Self {
        Self {
            n,
            rank,
            addr: addr.into(),
            resume: None,
            attempt: 0,
            local_fast: true,
            handshake_timeout: Duration::from_secs(30),
        }
    }
}

/// Process-global registry of leader control listeners, keyed by address.
/// The listener must outlive one attempt: auto-resume retries re-handshake
/// on the *same* address, and rebinding between attempts would race the
/// followers' reconnects (and lose an ephemeral `:0` port entirely).
static LISTENERS: OnceLock<Mutex<HashMap<String, Arc<TcpListener>>>> = OnceLock::new();

fn listener_registry() -> &'static Mutex<HashMap<String, Arc<TcpListener>>> {
    LISTENERS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Bind the coordinator control listener for `addr` (may be `host:0` for
/// an ephemeral port) and park it in the process-global registry; returns
/// the *actual* bound address, which is what followers must be given and
/// what [`TcpOpts::addr`] should carry.  Idempotent per returned address.
pub fn leader_bind(addr: &str) -> Result<String> {
    let mut reg = lock_clean(listener_registry());
    if reg.contains_key(addr) {
        return Ok(addr.to_string());
    }
    let l = TcpListener::bind(addr)?;
    let actual = l.local_addr()?.to_string();
    reg.insert(actual.clone(), Arc::new(l));
    Ok(actual)
}

fn timeout_err(what: &str) -> Error {
    Error::Io(std::io::Error::new(std::io::ErrorKind::TimedOut, what.to_string()))
}

/// Render an error for a lost-connection abort cause.  Uses the *inner*
/// I/O message so the cause does not contain the `"I/O error"` retryable
/// marker — see the module docs on why vanished peers must not be
/// retried.
fn io_msg(e: &Error) -> String {
    match e {
        Error::Io(io) => io.to_string(),
        other => format!("{other}"),
    }
}

/// Barrier-round routing state fed by the control reader threads and
/// drained by the [`BarrierLink`] waits.
#[derive(Default)]
struct BarrierMaps {
    /// Leader only: per `(bid, seq)` round, follower deposits by rank
    /// (index = rank − 1).
    reports: HashMap<(u8, u64), Vec<Option<Vec<u8>>>>,
    /// Followers only: per `(bid, seq)` round, the leader's decision.
    decisions: HashMap<(u8, u64), Vec<u8>>,
}

/// State shared between the cluster handle and its socket threads.
struct Shared {
    n: usize,
    rank: usize,
    abort: Arc<JobAbort>,
    /// Set once by [`TcpCluster::shutdown`]: subsequent socket errors and
    /// EOFs are expected, not peer deaths.
    closing: AtomicBool,
    /// Set by a control reader *before* it trips a remotely-received
    /// abort, so the poison hook does not echo the cause back across the
    /// hop it arrived on.
    remote_origin: AtomicBool,
    barrier: Mutex<BarrierMaps>,
    cond: Condvar,
    /// Control-socket write halves by peer rank (leader: one per
    /// follower; follower: index 0 only; own slot `None`).
    ctrl: Vec<Option<Mutex<TcpStream>>>,
}

impl Shared {
    fn closing(&self) -> bool {
        self.closing.load(Ordering::SeqCst)
    }

    /// Trip the job abort with a transport-level cause unless the job is
    /// already dead or shutting down.
    fn trip_if_live(&self, superstep: u64, cause: String) {
        if self.closing() || self.abort.aborted() {
            return;
        }
        self.abort.trip(AbortCause {
            machine: self.rank,
            unit: "net",
            superstep,
            cause,
        });
    }

    /// A reader observed the connection to `peer` die.  Wait briefly for
    /// the originating cause to arrive on the control plane (first cause
    /// wins job-wide, and the true origin beats our synthesized one), then
    /// trip with a `connection lost` cause if the job is still live.
    fn trip_lost_peer(&self, peer: usize, superstep: u64, err: Option<Error>) {
        let deadline = Instant::now() + LOST_PEER_GRACE;
        while Instant::now() < deadline {
            if self.closing() || self.abort.aborted() {
                return;
            }
            // analyze:allow(sleep-slicing): bounded grace poll — each nap
            // is ABORT_POLL and the abort latch is re-checked first.
            std::thread::sleep(ABORT_POLL);
        }
        let detail = match err {
            Some(e) => io_msg(&e),
            None => "peer closed without goodbye".to_string(),
        };
        self.trip_if_live(
            superstep,
            format!("connection to machine {peer} lost: {detail}"),
        );
    }

    /// Write one frame on the control socket towards `peer`.  `Ok` means
    /// the kernel accepted the bytes; errors are returned raw (callers
    /// decide whether they are trip-worthy).
    fn ctrl_write_raw(&self, peer: usize, kind: FrameKind, step: u64, body: &[u8]) -> Result<()> {
        let slot = self.ctrl.get(peer).and_then(|s| s.as_ref()).ok_or_else(|| {
            Error::CorruptStream(format!("no control socket towards machine {peer}"))
        })?;
        let mut sock = lock_clean(slot);
        frame::write_frame(&mut *sock, kind, self.rank as u32, step, body)
    }

    /// Barrier-path control write: a failure here means the round can
    /// never complete, so trip the latch and surface the first cause.
    fn ctrl_write(&self, peer: usize, kind: FrameKind, step: u64, body: &[u8]) -> Result<()> {
        match self.ctrl_write_raw(peer, kind, step, body) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.trip_if_live(
                    step,
                    format!("connection to machine {peer} lost: {}", io_msg(&e)),
                );
                Err(self.abort.first_cause_or(e))
            }
        }
    }

    /// Block until `f` yields, polling the abort latch: the typed abort
    /// error surfaces instead of a wedge when the job dies mid-round.
    fn wait_barrier<O>(&self, f: impl Fn(&mut BarrierMaps) -> Option<O>) -> Result<O> {
        let mut st = lock_clean(&self.barrier);
        loop {
            if let Some(o) = f(&mut st) {
                return Ok(o);
            }
            if let Some(c) = self.abort.cause() {
                return Err(c.to_error());
            }
            st = wait_timeout_clean(&self.cond, st, ABORT_POLL);
        }
    }
}

/// A connected TCP cluster: this process's view of the `n`-process job.
/// Returned by [`TcpCluster::connect`]; implements [`BarrierLink`] (the
/// distributed `Rendezvous` carrier) and [`Poisonable`] (the `JobAbort`
/// latch's remote trip path).  [`TcpCluster::shutdown`] is idempotent and
/// also runs on drop, so threads and sockets never outlive the job.
pub struct TcpCluster {
    shared: Arc<Shared>,
    /// The handshake's cluster-wide resume agreement (min of all local
    /// proposals; `None` if any machine had no usable checkpoint).
    agreed_resume: Option<u64>,
    /// Extra clones of the data sockets, for forced teardown.
    data_socks: Vec<Option<TcpStream>>,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl TcpCluster {
    /// Handshake with the coordinator, establish the full data mesh, spawn
    /// the per-peer socket threads, and return this rank's endpoint pair
    /// plus the ledger [`Switch`] (real sockets pace themselves; the
    /// switch only accounts the wire-vs-local byte split) and the cluster
    /// handle.  Blocks for at most [`TcpOpts::handshake_timeout`].
    pub fn connect(
        opts: TcpOpts,
        pool: Arc<BufPool>,
        abort: Arc<JobAbort>,
        tracer: &Arc<crate::trace::Tracer>,
    ) -> Result<((NetSender, NetReceiver), Arc<Switch>, Arc<TcpCluster>)> {
        if opts.rank >= opts.n {
            return Err(Error::Config(format!(
                "transport_rank {} out of range for {} machines",
                opts.rank, opts.n
            )));
        }
        let deadline = Instant::now() + opts.handshake_timeout;
        let mut tr = tracer.unit(opts.rank, "net");
        let hs = if opts.rank == 0 {
            handshake_leader(&opts, deadline, &abort, &mut tr)?
        } else {
            handshake_follower(&opts, deadline, &mut tr)?
        };
        let mesh = data_mesh(&opts, &hs, deadline, &mut tr)?;

        let shared = Arc::new(Shared {
            n: opts.n,
            rank: opts.rank,
            abort: abort.clone(),
            closing: AtomicBool::new(false),
            remote_origin: AtomicBool::new(false),
            barrier: Mutex::new(BarrierMaps::default()),
            cond: Condvar::new(),
            ctrl: hs.ctrl_write,
        });

        // Endpoint wiring: identical shapes to the sim backend.  txs[j]
        // feeds peer j's writer thread; txs[rank] is the loopback into our
        // own receiver queue; reader threads feed the same queue.
        let (rx_tx, rx) = channel::<Batch>();
        let switch = Switch::ledger(Some(abort.clone()));
        let mut threads: Vec<JoinHandle<()>> = Vec::new();
        let mut data_socks: Vec<Option<TcpStream>> = (0..opts.n).map(|_| None).collect();
        let mut txs: Vec<Option<Sender<Batch>>> = (0..opts.n).map(|_| None).collect();
        txs[opts.rank] = Some(rx_tx.clone());
        for (j, sock) in mesh.into_iter().enumerate() {
            let Some(sock) = sock else { continue };
            let wsock = sock.try_clone()?;
            let rsock = sock.try_clone()?;
            data_socks[j] = Some(sock);
            let (tx, out_rx) = channel::<Batch>();
            txs[j] = Some(tx);
            let (sh, pl) = (shared.clone(), pool.clone());
            threads.push(std::thread::spawn(move || writer_loop(&sh, j, wsock, out_rx, &pl)));
            let (sh, pl, fwd) = (shared.clone(), pool.clone(), rx_tx.clone());
            threads.push(std::thread::spawn(move || reader_loop(&sh, j, rsock, fwd, &pl)));
        }
        // Control reader threads: the leader watches every follower's
        // socket, a follower watches the leader's.
        for (peer, sock) in hs.ctrl_read.into_iter().enumerate() {
            let Some(sock) = sock else { continue };
            let sh = shared.clone();
            threads.push(std::thread::spawn(move || control_loop(&sh, peer, sock)));
        }
        tr.finish();

        let sender = NetSender {
            me: opts.rank,
            switch: switch.clone(),
            txs: txs.into_iter().map(|t| t.expect("tx built per rank")).collect(),
            sent_bytes: 0,
            local_bytes: 0,
            local_fast: opts.local_fast,
            abort: Some(abort.clone()),
        };
        let receiver = NetReceiver {
            me: opts.rank,
            rx,
            abort: Some(abort),
        };
        let cluster = Arc::new(TcpCluster {
            shared,
            agreed_resume: hs.agreed_resume,
            data_socks,
            threads: Mutex::new(threads),
        });
        Ok(((sender, receiver), switch, cluster))
    }

    /// The handshake's cluster-wide resume agreement.
    pub fn agreed_resume(&self) -> Option<u64> {
        self.agreed_resume
    }

    /// Number of machine processes in the cluster.
    pub fn peers(&self) -> usize {
        self.shared.n
    }

    /// This process's rank.
    pub fn rank(&self) -> usize {
        self.shared.rank
    }

    /// Tear the cluster down: mark closing, send `Goodbye` on the control
    /// plane, force every socket shut so blocked reads return, and join
    /// all socket threads.  Idempotent; also runs on drop.  Call after
    /// the machine thread has finished (success or failure) — the data
    /// writers have drained and half-closed by then.
    pub fn shutdown(&self) {
        let sh = &self.shared;
        if sh.closing.swap(true, Ordering::SeqCst) {
            return;
        }
        for peer in 0..sh.n {
            if peer != sh.rank && sh.ctrl[peer].is_some() {
                let _ = sh.ctrl_write_raw(peer, FrameKind::Goodbye, 0, &[]);
            }
        }
        for s in self.data_socks.iter().flatten() {
            let _ = s.shutdown(Shutdown::Both);
        }
        for slot in sh.ctrl.iter().flatten() {
            let _ = lock_clean(slot).shutdown(Shutdown::Both);
        }
        sh.cond.notify_all();
        let handles = std::mem::take(&mut *lock_clean(&self.threads));
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for TcpCluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl BarrierLink for TcpCluster {
    fn send_report(&self, bid: u8, seq: u64, payload: Vec<u8>) -> Result<()> {
        debug_assert_ne!(self.shared.rank, 0, "leader deposits locally");
        let mut body = Vec::with_capacity(1 + payload.len());
        body.push(bid);
        body.extend_from_slice(&payload);
        self.shared.ctrl_write(0, FrameKind::BarrierReport, seq, &body)
    }

    fn recv_reports(&self, bid: u8, seq: u64) -> Result<Vec<Vec<u8>>> {
        self.shared.wait_barrier(|maps| {
            let full = maps
                .reports
                .get(&(bid, seq))
                .is_some_and(|v| v.iter().all(Option::is_some));
            if !full {
                return None;
            }
            let v = maps.reports.remove(&(bid, seq)).unwrap();
            Some(v.into_iter().map(|p| p.unwrap()).collect())
        })
    }

    fn send_decision(&self, bid: u8, seq: u64, payload: Vec<u8>) -> Result<()> {
        let mut body = Vec::with_capacity(1 + payload.len());
        body.push(bid);
        body.extend_from_slice(&payload);
        for peer in 1..self.shared.n {
            self.shared
                .ctrl_write(peer, FrameKind::BarrierDecision, seq, &body)?;
        }
        Ok(())
    }

    fn recv_decision(&self, bid: u8, seq: u64) -> Result<Vec<u8>> {
        self.shared
            .wait_barrier(|maps| maps.decisions.remove(&(bid, seq)))
    }
}

impl Poisonable for TcpCluster {
    /// The remote trip path: broadcast the cause as an Abort control frame
    /// (leader → all followers; follower → leader, unless the cause itself
    /// arrived remotely) and force the data sockets shut so blocked reads
    /// observe the trip.  Send failures are ignored — the peer that cannot
    /// be reached is dead or closing, and either way already knows.
    fn poison(&self, cause: Arc<AbortCause>) {
        let sh = &self.shared;
        if !sh.closing() {
            let body = frame::encode_cause(
                cause.machine as u32,
                cause.unit,
                cause.superstep,
                &cause.cause,
            );
            if sh.rank == 0 {
                for peer in 1..sh.n {
                    let _ = sh.ctrl_write_raw(peer, FrameKind::Abort, cause.superstep, &body);
                }
            } else if !sh.remote_origin.load(Ordering::SeqCst) {
                let _ = sh.ctrl_write_raw(0, FrameKind::Abort, cause.superstep, &body);
            }
        }
        for s in self.data_socks.iter().flatten() {
            let _ = s.shutdown(Shutdown::Both);
        }
        sh.cond.notify_all();
    }
}

/// Handshake result: the roster plus the split control sockets.
struct Handshake {
    agreed_resume: Option<u64>,
    /// Every rank's data-plane address (index = rank; own entry unused).
    data_addrs: Vec<String>,
    /// This rank's bound data listener.
    data_listener: TcpListener,
    /// Control write halves by peer rank (wrapped later by [`Shared`]).
    ctrl_write: Vec<Option<Mutex<TcpStream>>>,
    /// Control read halves by peer rank.
    ctrl_read: Vec<Option<TcpStream>>,
}

/// Encode a Hello payload: resume proposal + data address.
fn encode_hello(resume: Option<u64>, data_addr: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + data_addr.len());
    out.extend_from_slice(&resume.unwrap_or(NO_RESUME).to_le_bytes());
    out.extend_from_slice(data_addr.as_bytes());
    out
}

fn decode_hello(b: &[u8]) -> Result<(Option<u64>, String)> {
    if b.len() < 8 {
        return Err(Error::CorruptStream("truncated hello payload".into()));
    }
    let r = u64::from_le_bytes(b[..8].try_into().unwrap());
    let resume = (r != NO_RESUME).then_some(r);
    let addr = std::str::from_utf8(&b[8..])
        .map_err(|_| Error::CorruptStream("non-utf8 data address in hello".into()))?
        .to_string();
    Ok((resume, addr))
}

/// Encode a Roster payload: agreed resume + every rank's data address.
fn encode_roster(agreed: Option<u64>, addrs: &[String]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&agreed.unwrap_or(NO_RESUME).to_le_bytes());
    for a in addrs {
        out.extend_from_slice(&(a.len() as u16).to_le_bytes());
        out.extend_from_slice(a.as_bytes());
    }
    out
}

fn decode_roster(b: &[u8], n: usize) -> Result<(Option<u64>, Vec<String>)> {
    let bad = || Error::CorruptStream("truncated roster payload".into());
    if b.len() < 8 {
        return Err(bad());
    }
    let r = u64::from_le_bytes(b[..8].try_into().unwrap());
    let agreed = (r != NO_RESUME).then_some(r);
    let mut addrs = Vec::with_capacity(n);
    let mut at = 8usize;
    for _ in 0..n {
        if b.len() < at + 2 {
            return Err(bad());
        }
        let len = u16::from_le_bytes([b[at], b[at + 1]]) as usize;
        at += 2;
        if b.len() < at + len {
            return Err(bad());
        }
        let a = std::str::from_utf8(&b[at..at + len])
            .map_err(|_| Error::CorruptStream("non-utf8 data address in roster".into()))?;
        addrs.push(a.to_string());
        at += len;
    }
    Ok((agreed, addrs))
}

/// Combine local resume proposals into the cluster agreement: resume is
/// only possible from a step *every* machine has durable (min); one
/// machine without a checkpoint forces a fresh start.
fn agree_resume(proposals: &[Option<u64>]) -> Option<u64> {
    proposals
        .iter()
        .copied()
        .reduce(|a, b| Some(a?.min(b?)))
        .flatten()
}

/// Bind this rank's ephemeral data-plane listener on the same interface
/// as its control-plane anchor.
fn bind_data_listener(anchor: SocketAddr) -> Result<(TcpListener, String)> {
    let l = TcpListener::bind(SocketAddr::new(anchor.ip(), 0))?;
    let addr = l.local_addr()?.to_string();
    Ok((l, addr))
}

/// Read exactly one frame off `sock` with the handshake deadline as a
/// read timeout (handshake sockets are dropped wholesale on error, so a
/// timeout cannot desync anything — unlike post-handshake reads, which
/// must stay blocking).
fn read_handshake_frame(
    sock: &mut TcpStream,
    deadline: Instant,
    buf: &mut Vec<u8>,
) -> Result<(FrameKind, u32, u64)> {
    let left = deadline
        .checked_duration_since(Instant::now())
        .ok_or_else(|| timeout_err("transport handshake timed out"))?;
    sock.set_read_timeout(Some(left))?;
    let got = frame::read_frame_into(sock, buf)?;
    got.ok_or_else(|| Error::CorruptStream("peer closed during handshake".into()))
}

/// Rank 0's side of the handshake: collect `n−1` Hellos, agree the resume
/// point, broadcast the Roster.
fn handshake_leader(
    opts: &TcpOpts,
    deadline: Instant,
    abort: &Arc<JobAbort>,
    tr: &mut crate::trace::UnitTracer,
) -> Result<Handshake> {
    let listener = {
        let mut reg = lock_clean(listener_registry());
        match reg.get(&opts.addr) {
            Some(l) => l.clone(),
            None => {
                let l = Arc::new(TcpListener::bind(&opts.addr)?);
                reg.insert(opts.addr.clone(), l.clone());
                l
            }
        }
    };
    let (data_listener, data_addr) = bind_data_listener(listener.local_addr()?)?;
    // Followers by rank: (control socket, resume proposal, data address).
    let mut peers: HashMap<usize, (TcpStream, Option<u64>, String)> = HashMap::new();
    listener.set_nonblocking(true)?;
    let mut buf = Vec::new();
    while peers.len() < opts.n - 1 {
        if let Some(c) = abort.cause() {
            return Err(c.to_error());
        }
        match listener.accept() {
            Ok((mut sock, _)) => {
                sock.set_nonblocking(false)?;
                // A malformed or stale connector is dropped, not fatal:
                // the expected peer may still be on its way.
                let hello = sock
                    .set_nodelay(true)
                    .map_err(Error::Io)
                    .and_then(|_| read_handshake_frame(&mut sock, deadline, &mut buf));
                if let Ok((FrameKind::Hello, src, step)) = hello {
                    if step == opts.attempt && (1..opts.n).contains(&(src as usize)) {
                        if let Ok((resume, addr)) = decode_hello(&buf) {
                            tr.instant(EventKind::Control, FrameKind::Hello as u64);
                            peers.insert(src as usize, (sock, resume, addr));
                        }
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(timeout_err(&format!(
                        "transport handshake timed out: {} of {} peers joined",
                        peers.len(),
                        opts.n - 1
                    )));
                }
                // analyze:allow(sleep-slicing): bounded handshake poll —
                // abort latch and deadline re-checked every slice.
                std::thread::sleep(ABORT_POLL);
            }
            Err(e) => return Err(Error::Io(e)),
        }
    }
    listener.set_nonblocking(false)?;

    let mut proposals: Vec<Option<u64>> = vec![opts.resume];
    let mut data_addrs: Vec<String> = vec![data_addr];
    for rank in 1..opts.n {
        let (_, resume, addr) = &peers[&rank];
        proposals.push(*resume);
        data_addrs.push(addr.clone());
    }
    let agreed = agree_resume(&proposals);
    let roster = encode_roster(agreed, &data_addrs);
    let mut ctrl_write: Vec<Option<Mutex<TcpStream>>> = (0..opts.n).map(|_| None).collect();
    let mut ctrl_read: Vec<Option<TcpStream>> = (0..opts.n).map(|_| None).collect();
    for (rank, (mut sock, _, _)) in peers {
        sock.set_read_timeout(None)?;
        frame::write_frame(&mut sock, FrameKind::Roster, 0, opts.attempt, &roster)?;
        tr.instant(EventKind::Control, FrameKind::Roster as u64);
        ctrl_read[rank] = Some(sock.try_clone()?);
        ctrl_write[rank] = Some(Mutex::new(sock));
    }
    Ok(Handshake {
        agreed_resume: agreed,
        data_addrs,
        data_listener,
        ctrl_write,
        ctrl_read,
    })
}

/// A follower's side of the handshake: connect, Hello, await the Roster.
fn handshake_follower(
    opts: &TcpOpts,
    deadline: Instant,
    tr: &mut crate::trace::UnitTracer,
) -> Result<Handshake> {
    let mut sock = loop {
        match TcpStream::connect(&opts.addr) {
            Ok(s) => break s,
            Err(_) if Instant::now() < deadline => {
                // analyze:allow(sleep-slicing): bounded connect retry; the
                // coordinator may simply not have bound yet.
                std::thread::sleep(ABORT_POLL);
            }
            Err(e) => {
                return Err(Error::Io(std::io::Error::new(
                    e.kind(),
                    format!("transport handshake timed out connecting to coordinator {}: {e}", opts.addr),
                )))
            }
        }
    };
    sock.set_nodelay(true)?;
    let (data_listener, data_addr) = bind_data_listener(sock.local_addr()?)?;
    frame::write_frame(
        &mut sock,
        FrameKind::Hello,
        opts.rank as u32,
        opts.attempt,
        &encode_hello(opts.resume, &data_addr),
    )?;
    tr.instant(EventKind::Control, FrameKind::Hello as u64);
    let mut buf = Vec::new();
    let (kind, _, step) = read_handshake_frame(&mut sock, deadline, &mut buf)?;
    if kind != FrameKind::Roster || step != opts.attempt {
        return Err(Error::CorruptStream(format!(
            "expected roster for attempt {}, got {kind:?} (attempt {step})",
            opts.attempt
        )));
    }
    let (agreed, data_addrs) = decode_roster(&buf, opts.n)?;
    tr.instant(EventKind::Control, FrameKind::Roster as u64);
    sock.set_read_timeout(None)?;
    let mut ctrl_write: Vec<Option<Mutex<TcpStream>>> = (0..opts.n).map(|_| None).collect();
    let mut ctrl_read: Vec<Option<TcpStream>> = (0..opts.n).map(|_| None).collect();
    ctrl_read[0] = Some(sock.try_clone()?);
    ctrl_write[0] = Some(Mutex::new(sock));
    Ok(Handshake {
        agreed_resume: agreed,
        data_addrs,
        data_listener,
        ctrl_write,
        ctrl_read,
    })
}

/// Establish the full data mesh: initiate to every lower rank, accept from
/// every higher one; exactly one socket per pair, identified by a Hello
/// frame from the initiator.  Returns sockets by peer rank.
fn data_mesh(
    opts: &TcpOpts,
    hs: &Handshake,
    deadline: Instant,
    tr: &mut crate::trace::UnitTracer,
) -> Result<Vec<Option<TcpStream>>> {
    let mut socks: Vec<Option<TcpStream>> = (0..opts.n).map(|_| None).collect();
    for (peer, addr) in hs.data_addrs.iter().enumerate().take(opts.rank) {
        let mut sock = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(_) if Instant::now() < deadline => {
                    // analyze:allow(sleep-slicing): bounded connect retry
                    // against a peer listener bound before its Hello.
                    std::thread::sleep(ABORT_POLL);
                }
                Err(e) => {
                    return Err(Error::Io(std::io::Error::new(
                        e.kind(),
                        format!("data-plane connect to machine {peer} ({addr}) failed: {e}"),
                    )))
                }
            }
        };
        sock.set_nodelay(true)?;
        frame::write_frame(&mut sock, FrameKind::Hello, opts.rank as u32, opts.attempt, &[])?;
        tr.instant(EventKind::Connect, peer as u64);
        socks[peer] = Some(sock);
    }
    let mut buf = Vec::new();
    hs.data_listener.set_nonblocking(true)?;
    while socks
        .iter()
        .enumerate()
        .any(|(j, s)| j != opts.rank && s.is_none())
    {
        match hs.data_listener.accept() {
            Ok((mut sock, _)) => {
                sock.set_nonblocking(false)?;
                let hello = sock
                    .set_nodelay(true)
                    .map_err(Error::Io)
                    .and_then(|_| read_handshake_frame(&mut sock, deadline, &mut buf));
                if let Ok((FrameKind::Hello, src, step)) = hello {
                    let src = src as usize;
                    if step == opts.attempt && src > opts.rank && src < opts.n {
                        sock.set_read_timeout(None)?;
                        tr.instant(EventKind::Connect, src as u64);
                        socks[src] = Some(sock);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    let missing: Vec<usize> = socks
                        .iter()
                        .enumerate()
                        .filter(|(j, s)| *j != opts.rank && s.is_none())
                        .map(|(j, _)| j)
                        .collect();
                    return Err(timeout_err(&format!(
                        "data-plane handshake timed out waiting for machines {missing:?}"
                    )));
                }
                // analyze:allow(sleep-slicing): bounded accept poll.
                std::thread::sleep(ABORT_POLL);
            }
            Err(e) => return Err(Error::Io(e)),
        }
    }
    for s in socks.iter().flatten() {
        s.set_read_timeout(None)?;
    }
    Ok(socks)
}

/// Per-peer data-plane writer: drain the machine's outgoing queue for one
/// peer, frame each batch onto the socket, recycle the sent buffer.  On
/// clean disconnect (every `NetSender` clone dropped with the job alive)
/// it appends a `Goodbye` and half-closes, so the peer's reader can tell
/// shutdown from death.
fn writer_loop(sh: &Shared, peer: usize, mut sock: TcpStream, out: Receiver<Batch>, pool: &BufPool) {
    loop {
        match out.recv_timeout(ABORT_POLL) {
            Ok(b) => {
                let (kind, data) = match b.payload {
                    Payload::Data(d) => (FrameKind::Data, Some(d)),
                    Payload::End => (FrameKind::End, None),
                    Payload::Load(d) => (FrameKind::Load, Some(d)),
                    Payload::LoadEnd => (FrameKind::LoadEnd, None),
                };
                let res = frame::write_frame(
                    &mut sock,
                    kind,
                    b.src as u32,
                    b.step,
                    data.as_deref().unwrap_or(&[]),
                );
                if let Some(d) = data {
                    pool.put(d);
                }
                if let Err(e) = res {
                    sh.trip_if_live(
                        b.step,
                        format!("connection to machine {peer} lost while sending: {}", io_msg(&e)),
                    );
                    break;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if sh.abort.aborted() {
                    break;
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                if !sh.abort.aborted() {
                    let _ = frame::write_frame(&mut sock, FrameKind::Goodbye, sh.rank as u32, 0, &[]);
                }
                break;
            }
        }
    }
    let _ = sock.shutdown(Shutdown::Write);
}

/// Per-peer data-plane reader: read frames into recycled pool blocks and
/// feed them to the machine's receiver queue.  EOF without a preceding
/// `Goodbye` (and any read error outside shutdown) is a peer death.
fn reader_loop(sh: &Shared, peer: usize, mut sock: TcpStream, fwd: Sender<Batch>, pool: &BufPool) {
    let mut goodbye = false;
    let mut last_step = 0u64;
    loop {
        let mut payload = pool.take();
        match frame::read_frame_into(&mut sock, &mut payload) {
            Ok(Some((kind, src, step))) => {
                last_step = step;
                let p = match kind {
                    FrameKind::Data => Payload::Data(payload),
                    FrameKind::Load => Payload::Load(payload),
                    FrameKind::End => {
                        pool.put(payload);
                        Payload::End
                    }
                    FrameKind::LoadEnd => {
                        pool.put(payload);
                        Payload::LoadEnd
                    }
                    FrameKind::Goodbye => {
                        pool.put(payload);
                        goodbye = true;
                        continue;
                    }
                    other => {
                        pool.put(payload);
                        sh.trip_if_live(
                            step,
                            format!("unexpected {other:?} frame on data socket from machine {peer}"),
                        );
                        break;
                    }
                };
                if fwd
                    .send(Batch {
                        src: src as usize,
                        step,
                        payload: p,
                    })
                    .is_err()
                {
                    // Receiver gone: the local machine already finished.
                    break;
                }
            }
            Ok(None) => {
                pool.put(payload);
                if !goodbye {
                    sh.trip_lost_peer(peer, last_step, None);
                }
                break;
            }
            Err(e) => {
                pool.put(payload);
                sh.trip_lost_peer(peer, last_step, Some(e));
                break;
            }
        }
    }
}

/// Control-plane reader: route barrier rounds, apply remote aborts, and
/// watch the peer's liveness.  Runs per follower socket on the leader,
/// and once (towards the leader) on a follower.
fn control_loop(sh: &Arc<Shared>, peer: usize, mut sock: TcpStream) {
    let mut goodbye = false;
    let mut buf = Vec::new();
    loop {
        match frame::read_frame_into(&mut sock, &mut buf) {
            Ok(Some((kind, src, step))) => match kind {
                FrameKind::BarrierReport if sh.rank == 0 && !buf.is_empty() => {
                    let bid = buf[0];
                    let idx = (src as usize).wrapping_sub(1);
                    {
                        let mut maps = lock_clean(&sh.barrier);
                        let slot = maps
                            .reports
                            .entry((bid, step))
                            .or_insert_with(|| vec![None; sh.n - 1]);
                        if idx < slot.len() {
                            slot[idx] = Some(buf[1..].to_vec());
                        }
                    }
                    sh.cond.notify_all();
                }
                FrameKind::BarrierDecision if sh.rank != 0 && !buf.is_empty() => {
                    let bid = buf[0];
                    {
                        let mut maps = lock_clean(&sh.barrier);
                        maps.decisions.insert((bid, step), buf[1..].to_vec());
                    }
                    sh.cond.notify_all();
                }
                FrameKind::Abort => {
                    // Remote-origin first: the poison hook must not echo
                    // this cause back across the hop it arrived on.
                    sh.remote_origin.store(true, Ordering::SeqCst);
                    let cause = match frame::decode_cause(&buf) {
                        Ok((m, u, s, c)) => AbortCause {
                            machine: m as usize,
                            unit: u,
                            superstep: s,
                            cause: c,
                        },
                        Err(_) => AbortCause {
                            machine: src as usize,
                            unit: "net",
                            superstep: step,
                            cause: "remote abort with garbled cause".into(),
                        },
                    };
                    sh.abort.trip(cause);
                    sh.cond.notify_all();
                }
                FrameKind::Goodbye => goodbye = true,
                other => {
                    sh.trip_if_live(
                        step,
                        format!("unexpected {other:?} frame on control socket from machine {peer}"),
                    );
                    break;
                }
            },
            Ok(None) => {
                if !goodbye {
                    sh.trip_lost_peer(peer, 0, None);
                }
                break;
            }
            Err(e) => {
                sh.trip_lost_peer(peer, 0, Some(e));
                break;
            }
        }
    }
    sh.cond.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_and_roster_roundtrip() {
        let h = encode_hello(Some(12), "127.0.0.1:4000");
        assert_eq!(decode_hello(&h).unwrap(), (Some(12), "127.0.0.1:4000".into()));
        let h = encode_hello(None, "x:1");
        assert_eq!(decode_hello(&h).unwrap(), (None, "x:1".into()));
        assert!(decode_hello(&[1, 2]).is_err());

        let addrs: Vec<String> = vec!["a:1".into(), "bb:22".into(), "ccc:333".into()];
        let r = encode_roster(Some(7), &addrs);
        assert_eq!(decode_roster(&r, 3).unwrap(), (Some(7), addrs.clone()));
        let r = encode_roster(None, &addrs);
        assert_eq!(decode_roster(&r, 3).unwrap().0, None);
        assert!(decode_roster(&r[..r.len() - 1], 3).is_err());
    }

    #[test]
    fn resume_agreement_is_min_and_requires_all() {
        assert_eq!(agree_resume(&[Some(5), Some(3), Some(9)]), Some(3));
        assert_eq!(agree_resume(&[Some(5), None, Some(9)]), None);
        assert_eq!(agree_resume(&[None]), None);
        assert_eq!(agree_resume(&[Some(2)]), Some(2));
    }

    /// Two in-process "ranks" handshake and exchange data + barrier + abort
    /// traffic over real loopback sockets: the full cluster lifecycle in
    /// one test, without worker processes.
    #[test]
    fn two_rank_loopback_cluster_end_to_end() {
        let addr = leader_bind("127.0.0.1:0").unwrap();
        let mk = |rank: usize, resume: Option<u64>| {
            let mut o = TcpOpts::new(2, rank, addr.clone());
            o.resume = resume;
            o.handshake_timeout = Duration::from_secs(10);
            o
        };
        let pool = BufPool::new(16);
        let tracer = Arc::new(crate::trace::Tracer::new(crate::trace::TraceConfig::default()));
        let a0 = JobAbort::new();
        let a1 = JobAbort::new();
        let (p0, t0) = (pool.clone(), tracer.clone());
        let (o0, o1) = (mk(0, Some(4)), mk(1, Some(2)));
        let h = std::thread::spawn(move || TcpCluster::connect(o0, p0, a0, &t0));
        let ((mut s1, r1), _, c1) = TcpCluster::connect(o1, pool, a1, &tracer).unwrap();
        let ((mut s0, r0), sw0, c0) = h.join().unwrap().unwrap();

        // Resume agreement: min(4, 2) = 2 on both sides.
        assert_eq!(c0.agreed_resume(), Some(2));
        assert_eq!(c1.agreed_resume(), Some(2));

        // Data plane: both directions, plus loopback-to-self.
        s0.send(1, 3, Payload::Data(vec![9, 9])).unwrap();
        s0.send(0, 3, Payload::End).unwrap();
        s1.send(0, 3, Payload::Data(vec![7])).unwrap();
        let b = r1.recv().unwrap();
        assert_eq!((b.src, b.step), (0, 3));
        assert!(matches!(b.payload, Payload::Data(ref d) if d == &vec![9, 9]));
        let mut got = vec![r0.recv().unwrap(), r0.recv().unwrap()];
        got.sort_by_key(|b| b.src);
        assert!(matches!(got[0].payload, Payload::End));
        assert!(matches!(got[1].payload, Payload::Data(ref d) if d == &vec![7]));
        // The ledger accounted wire bytes without sleeping.
        assert!(sw0.total_bytes() > 0);

        // Barrier round over the control plane (leader = rank 0).
        let c0b = c0.clone();
        let lead = std::thread::spawn(move || {
            let reports = c0b.recv_reports(BARRIER_UC, 0).unwrap();
            assert_eq!(reports, vec![vec![42u8]]);
            c0b.send_decision(BARRIER_UC, 0, vec![1, 2, 3]).unwrap();
        });
        c1.send_report(BARRIER_UC, 0, vec![42]).unwrap();
        assert_eq!(c1.recv_decision(BARRIER_UC, 0).unwrap(), vec![1, 2, 3]);
        lead.join().unwrap();

        // Remote abort propagation: rank 1 trips locally; rank 0 observes
        // the originating cause (via its registered cluster poison hook it
        // would also relay — registration is the engine's job, so here we
        // watch the latch directly).
        c0.shared.abort.register(c0.clone() as Arc<dyn Poisonable>);
        c1.shared.abort.register(c1.clone() as Arc<dyn Poisonable>);
        c1.shared.abort.trip(AbortCause {
            machine: 1,
            unit: "U_s",
            superstep: 8,
            cause: "injected fault: transient network send failure".into(),
        });
        let deadline = Instant::now() + Duration::from_secs(5);
        while !c0.shared.abort.aborted() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let cause = c0.shared.abort.cause().expect("abort crossed processes");
        assert_eq!((cause.machine, cause.unit, cause.superstep), (1, "U_s", 8));
        assert!(cause.cause.contains("transient"));

        c1.shutdown();
        c0.shutdown();
    }
}
