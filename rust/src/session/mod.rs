//! The fluent GraphD session API — the single entry point for the paper's
//! three-phase pipeline: **Load** (§3.4) → **IO-Recoding** (§5) →
//! **Compute** (§3–§4).
//!
//! One builder yields a [`Session`]; [`Session::load`] materialises a
//! [`GraphSource`] into per-machine stores and returns a [`LoadedGraph`]
//! that owns the stores and the engine; jobs run through a per-job
//! [`JobBuilder`] that folds in what used to be scattered entry points:
//! execution mode ([`Mode::Auto`] resolution), XLA-kernel detection
//! ([`Xla`]), checkpointing and resume (§3.4).
//!
//! ```ignore
//! use graphd::{GraphD, GraphSource, Mode};
//!
//! let session = GraphD::builder()
//!     .machines(4)
//!     .workdir(&wd)
//!     .max_supersteps(10)
//!     .build()?;
//! let mut graph = session.load(GraphSource::InMemory(&g))?;
//! let basic = graph.run(Arc::new(PageRank::new(10)))?;          // IO-Basic
//! let recoded = graph.recode()?                                 // IO-Recoding
//!     .job(Arc::new(PageRank::new(10)))
//!     .mode(Mode::Auto)                                         // IO-Recoded (+XLA if artifacts)
//!     .run()?;
//! ```
//!
//! The old free functions (`engine::load::load_text`, `engine::run::run_job`)
//! survive as thin deprecated shims over the same internals.

use crate::api::VertexProgram;
use crate::config::{ClusterProfile, JobConfig, Mode};
use crate::dfs::Dfs;
use crate::engine::run::JobResult;
use crate::engine::{load as engine_load, run as engine_run, Engine};
use crate::error::{Error, Result};
use crate::ft::CheckpointCfg;
use crate::graph::generator::Dataset;
use crate::graph::Graph;
use crate::recode;
use crate::runtime::{self, KernelSet};
use crate::util::timer::timed;
use crate::worker::{MachineStore, Partitioning};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// XLA block-kernel policy for a session or a single job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Xla {
    /// Use the AOT kernels iff artifacts are present in the artifacts
    /// directory (missing artifacts fall back to the scalar path).
    Auto,
    /// Request the kernels unconditionally (a present-but-corrupt artifact
    /// is then a job error; absent artifacts still fall back to scalar).
    On,
    /// Scalar Rust only.
    Off,
}

/// Marker type carrying the builder entry point: `GraphD::builder()`.
pub struct GraphD;

impl GraphD {
    /// Start configuring a session.  Defaults: the `test` cluster profile
    /// with 4 machines, paper-default job tunables, a pid-scoped temp
    /// workdir, and `Xla::Auto`.
    pub fn builder() -> GraphDBuilder {
        GraphDBuilder::default()
    }
}

/// Fluent configuration for a [`Session`].
pub struct GraphDBuilder {
    profile: ClusterProfile,
    cfg: JobConfig,
    xla: Xla,
    dfs_block_size: Option<u64>,
    overrides: Vec<(String, String)>,
}

impl Default for GraphDBuilder {
    fn default() -> Self {
        // Process-unique counter so two default-built sessions in one
        // process never share (and clobber) store directories.
        static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut cfg = JobConfig::default();
        cfg.workdir = std::env::temp_dir()
            .join(format!("graphd_session_{}_{}", std::process::id(), seq));
        Self {
            profile: ClusterProfile::test(4),
            cfg,
            xla: Xla::Auto,
            dfs_block_size: None,
            overrides: Vec::new(),
        }
    }
}

impl GraphDBuilder {
    /// Replace the whole cluster profile (resets any earlier `machines`).
    pub fn profile(mut self, p: ClusterProfile) -> Self {
        self.profile = p;
        self
    }

    /// Number of simulated machines (worker threads).
    pub fn machines(mut self, n: usize) -> Self {
        self.profile.machines = n;
        self
    }

    /// Working-directory root; each machine stores under `<root>/m<i>/`,
    /// the session DFS under `<root>/dfs/`.
    pub fn workdir(mut self, p: impl Into<PathBuf>) -> Self {
        self.cfg.workdir = p.into();
        self
    }

    /// Session-default maximum supersteps (0 = unlimited); jobs can
    /// override per run via [`JobBuilder::max_supersteps`].
    pub fn max_supersteps(mut self, n: u64) -> Self {
        self.cfg.max_supersteps = n;
        self
    }

    /// Session-default execution mode (jobs override via [`JobBuilder::mode`]).
    pub fn mode(mut self, m: Mode) -> Self {
        self.cfg.mode = m;
        self
    }

    /// Stream in-memory buffer size b (bytes).
    pub fn stream_buf(mut self, b: usize) -> Self {
        self.cfg.stream_buf = b;
        self
    }

    /// Splittable-stream file cap ℬ (bytes).
    pub fn oms_file_cap(mut self, b: usize) -> Self {
        self.cfg.oms_file_cap = b;
        self
    }

    /// Merge-sort fan-in k.
    pub fn merge_k(mut self, k: usize) -> Self {
        self.cfg.merge_k = k;
        self
    }

    /// Keep OMS files until the next checkpoint (message-log recovery).
    pub fn keep_oms_for_recovery(mut self, keep: bool) -> Self {
        self.cfg.keep_oms_for_recovery = keep;
        self
    }

    /// Session-default stall-and-send ablation switch.
    pub fn disable_oms(mut self, d: bool) -> Self {
        self.cfg.disable_oms = d;
        self
    }

    /// Local-delivery fast path (default on), in every mode: `dst == me`
    /// traffic bypasses the simulated switch and the OMS files — recoded
    /// digesting folds local messages straight into the machine's own
    /// `A_r` shard, and the sorted-`S^I` modes route them through the
    /// local spill lane.  Turn off to measure the pre-fast-path routing
    /// (every batch through switch + OMS).
    pub fn local_fastpath(mut self, on: bool) -> Self {
        self.cfg.local_fastpath = on;
        self
    }

    /// Session-default adjacency residency (see [`crate::config::Resident`]):
    /// `Stream` re-reads `se.bin` every superstep (§3, the default), `Mmap`
    /// maps the materialized CSR files (semi-external-memory mode), `Auto`
    /// maps when they fit `-c resident_budget`.  Per-job override:
    /// [`JobBuilder::resident`].
    pub fn resident(mut self, r: crate::config::Resident) -> Self {
        self.cfg.resident = r;
        self
    }

    /// XLA policy: `true` ⇒ [`Xla::Auto`], `false` ⇒ [`Xla::Off`].
    pub fn use_xla(mut self, on: bool) -> Self {
        self.xla = if on { Xla::Auto } else { Xla::Off };
        self
    }

    /// Explicit XLA policy.
    pub fn xla(mut self, x: Xla) -> Self {
        self.xla = x;
        self
    }

    /// Directory holding the AOT `*.hlo.txt` artifacts (default:
    /// [`KernelSet::default_dir`]).
    pub fn artifacts_dir(mut self, p: impl Into<PathBuf>) -> Self {
        self.cfg.artifacts_dir = Some(p.into());
        self
    }

    /// Simulated-HDFS block size for this session's DFS.
    pub fn dfs_block_size(mut self, bs: u64) -> Self {
        self.dfs_block_size = Some(bs);
        self
    }

    /// Queue a raw `key=value` override (the CLI's `-c key=val` flags);
    /// applied — and validated — at [`Self::build`] time.
    pub fn config(mut self, key: &str, val: &str) -> Self {
        self.overrides.push((key.to_string(), val.to_string()));
        self
    }

    /// Validate the configuration, create the workdir + session DFS, and
    /// return the [`Session`].
    pub fn build(self) -> Result<Session> {
        let mut cfg = self.cfg;
        let mut xla = self.xla;
        for (k, v) in &self.overrides {
            cfg.apply(k, v)?;
            if k == "use_xla" {
                xla = if cfg.use_xla { Xla::Auto } else { Xla::Off };
            }
        }
        if self.profile.machines == 0 {
            return Err(Error::Config("a session needs at least 1 machine".into()));
        }
        std::fs::create_dir_all(&cfg.workdir)?;
        let mut dfs = Dfs::new(&cfg.workdir.join("dfs"))?;
        if let Some(bs) = self.dfs_block_size {
            dfs = dfs.with_block_size(bs);
        }
        Ok(Session {
            profile: self.profile,
            cfg,
            dfs,
            xla,
        })
    }
}

/// Where [`Session::load`] gets its graph from.
pub enum GraphSource<'a> {
    /// A text file already on the session DFS (`session.dfs().put(..)` or
    /// an earlier job's output).  `directed` drives the ID-recoding
    /// protocol choice (3 supersteps vs the 1-round undirected shortcut).
    Text {
        name: String,
        weighted: bool,
        directed: bool,
    },
    /// An in-memory graph, written to the session DFS with its dense IDs.
    InMemory(&'a Graph),
    /// An in-memory graph written through a sparse old-ID mapping (seeded),
    /// like real web inputs; the mapping is kept on the [`LoadedGraph`].
    InMemorySparse(&'a Graph, u64),
    /// A dataset preset at the given scale factor.
    Generate(Dataset, f64),
}

/// One configured GraphD session: cluster profile + job defaults + DFS.
pub struct Session {
    profile: ClusterProfile,
    cfg: JobConfig,
    dfs: Dfs,
    xla: Xla,
}

impl Session {
    /// The simulated cluster profile this session runs on.
    pub fn profile(&self) -> &ClusterProfile {
        &self.profile
    }

    /// The session-default job configuration (per-job knobs are overridden
    /// through [`JobBuilder`]).
    pub fn config(&self) -> &JobConfig {
        &self.cfg
    }

    /// The session's simulated DFS.
    pub fn dfs(&self) -> &Dfs {
        &self.dfs
    }

    /// The session's working-directory root.
    pub fn workdir(&self) -> &Path {
        &self.cfg.workdir
    }

    /// The artifacts directory consulted by `Xla::Auto` detection.
    pub fn artifacts_dir(&self) -> PathBuf {
        self.cfg
            .artifacts_dir
            .clone()
            .unwrap_or_else(KernelSet::default_dir)
    }

    fn engine(&self) -> Result<Engine> {
        Engine::new(self.profile.clone(), self.cfg.clone())
    }

    /// The paper's "Load" phase: materialise `src` into per-machine stores
    /// (state array `A` in memory, edge stream `S^E` on disk).
    pub fn load(&self, src: GraphSource<'_>) -> Result<LoadedGraph<'_>> {
        let engine = self.engine()?;
        let (name, weighted, directed, id_map) = match src {
            GraphSource::Text {
                name,
                weighted,
                directed,
            } => {
                if !self.dfs.exists(&name) {
                    return Err(Error::Config(format!(
                        "GraphSource::Text: '{name}' not on the session DFS"
                    )));
                }
                (name, weighted, directed, None)
            }
            GraphSource::InMemory(g) => {
                engine_load::put_graph(&self.dfs, "graph.txt", g, None)?;
                ("graph.txt".to_string(), g.weighted, g.directed, None)
            }
            GraphSource::InMemorySparse(g, seed) => {
                let ids = engine_load::put_graph(&self.dfs, "graph.txt", g, Some(seed))?;
                ("graph.txt".to_string(), g.weighted, g.directed, ids)
            }
            GraphSource::Generate(ds, scale) => {
                let g = ds.generate_scaled(scale);
                engine_load::put_graph(&self.dfs, "graph.txt", &g, None)?;
                ("graph.txt".to_string(), g.weighted, g.directed, None)
            }
        };
        let (load_secs, stores) =
            timed(|| engine_load::load_text_impl(&engine, &self.dfs, &name, weighted));
        Ok(LoadedGraph {
            session: self,
            engine,
            stores: stores?,
            recoded: None,
            directed,
            weighted,
            id_map,
            load_secs,
            recode_secs: None,
        })
    }

    /// Convenience: load `src` and run `program` with the session defaults
    /// in one call.
    pub fn run<P: VertexProgram>(
        &self,
        src: GraphSource<'_>,
        program: Arc<P>,
    ) -> Result<JobResult<P>> {
        self.load(src)?.run(program)
    }
}

/// A loaded graph: owns the per-machine stores, the engine handle, and —
/// after [`Self::recode`] — the recoded store generation.
pub struct LoadedGraph<'s> {
    session: &'s Session,
    engine: Engine,
    stores: Vec<MachineStore>,
    recoded: Option<Vec<MachineStore>>,
    directed: bool,
    weighted: bool,
    id_map: Option<Vec<u32>>,
    /// Wall-clock seconds of the parallel text load.
    pub load_secs: f64,
    /// Wall-clock seconds of ID recoding (set by [`Self::recode`]).
    pub recode_secs: Option<f64>,
}

impl<'s> LoadedGraph<'s> {
    /// The IO-Basic store generation.
    pub fn stores(&self) -> &[MachineStore] {
        &self.stores
    }

    /// The recoded store generation, if [`Self::recode`] has run.
    pub fn recoded_stores(&self) -> Option<&[MachineStore]> {
        self.recoded.as_deref()
    }

    /// Has [`Self::recode`] produced the recoded store generation?
    pub fn is_recoded(&self) -> bool {
        self.recoded.is_some()
    }

    /// Was the input graph directed?
    pub fn directed(&self) -> bool {
        self.directed
    }

    /// Does the input graph carry edge weights?
    pub fn weighted(&self) -> bool {
        self.weighted
    }

    /// Dense-ID → input-ID mapping when the session wrote the graph with
    /// sparse IDs ([`GraphSource::InMemorySparse`]).
    pub fn id_map(&self) -> Option<&[u32]> {
        self.id_map.as_deref()
    }

    /// The session-default job configuration (the serve subsystem reads
    /// its trace knob and workdir through this).
    pub(crate) fn session_cfg(&self) -> &JobConfig {
        &self.session.cfg
    }

    /// The paper's "IO-Recoding" phase (§5): produce the dense-ID store
    /// generation under `<workdir>/m<i>/rec/`.  Idempotent; records
    /// [`Self::recode_secs`] on first run.
    pub fn recode(&mut self) -> Result<&mut Self> {
        if self.recoded.is_none() {
            let (secs, rec) =
                timed(|| recode::recode(&self.engine, &self.stores, self.directed));
            self.recoded = Some(rec?);
            self.recode_secs = Some(secs);
        }
        Ok(self)
    }

    /// Re-read the recoded stores from local disks (the paper's "load
    /// graph from local disks" cost), replacing the in-memory handles.
    /// Returns the elapsed seconds.
    pub fn reload_recoded(&mut self) -> Result<f64> {
        if self.recoded.is_none() {
            return Err(Error::Config(
                "reload_recoded() requires recode() to have run".into(),
            ));
        }
        let (secs, rec) = timed(|| engine_load::load_local(&self.engine, "rec"));
        self.recoded = Some(rec?);
        Ok(secs)
    }

    /// Translate an input-space vertex ID into the current ID space: the
    /// identity before recoding, the §5 bijection (`pos·n + i`) after.
    /// Panics if the vertex does not exist.
    pub fn current_id_of(&self, input_id: u32) -> u32 {
        self.try_current_id_of(input_id).expect("vertex must exist")
    }

    /// Non-panicking [`Self::current_id_of`]: `None` when `input_id` is not
    /// a vertex of this graph (the serve subsystem's query validation).
    pub fn try_current_id_of(&self, input_id: u32) -> Option<u32> {
        match &self.recoded {
            None => {
                let n = self.stores.len();
                let m = Partitioning::Hashed.machine_of(input_id, n);
                self.stores[m]
                    .ids
                    .binary_search(&input_id)
                    .ok()
                    .map(|_| input_id)
            }
            Some(rec) => {
                let n = rec.len();
                let m = Partitioning::Hashed.machine_of(input_id, n);
                rec[m]
                    .ids
                    .binary_search(&input_id)
                    .ok()
                    .map(|pos| (pos * n + m) as u32)
            }
        }
    }

    /// Start a resident query server over this graph (the `graphd::serve`
    /// subsystem): point-to-point / single-source queries are admitted to a
    /// queue and served in k-lane batched traversals that share one
    /// superstep loop — and therefore one `S^E` stream pass per superstep.
    /// Recode first ([`Self::recode`]) to serve from the in-memory
    /// digesting path (§5).
    pub fn serve(
        &self,
        cfg: crate::serve::ServeConfig,
    ) -> Result<crate::serve::QueryServer<'_, 's>> {
        crate::serve::QueryServer::new(self, cfg)
    }

    /// Run `program` with the session defaults (equivalent to
    /// `self.job(program).run()`).
    pub fn run<P: VertexProgram>(&self, program: Arc<P>) -> Result<JobResult<P>> {
        self.job(program).run()
    }

    /// Start configuring a single job over this graph.
    pub fn job<P: VertexProgram>(&self, program: Arc<P>) -> JobBuilder<'_, 's, P> {
        JobBuilder {
            mode: self.session.cfg.mode,
            xla: self.session.xla,
            graph: self,
            program,
            max_supersteps: None,
            checkpoint: None,
            resume: None,
            disable_oms: None,
            local_fastpath: None,
            trace: None,
            retry: None,
            faults: None,
            resident: None,
        }
    }
}

/// What a [`JobBuilder`] resolved its `Auto` knobs to (also the shape of
/// the job the engine will actually run).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobPlan {
    /// `Basic` or `Recoded` — never `Auto`.
    pub mode: Mode,
    /// Whether the job will request the XLA block kernels.
    pub use_xla: bool,
    /// Whether HLO artifacts were found in the artifacts directory.
    pub artifacts_present: bool,
}

/// Per-job configuration: mode, superstep cap, checkpointing, resume, XLA.
pub struct JobBuilder<'g, 's, P: VertexProgram> {
    graph: &'g LoadedGraph<'s>,
    program: Arc<P>,
    mode: Mode,
    xla: Xla,
    max_supersteps: Option<u64>,
    checkpoint: Option<CheckpointCfg>,
    resume: Option<u64>,
    disable_oms: Option<bool>,
    local_fastpath: Option<bool>,
    trace: Option<crate::trace::TraceConfig>,
    retry: Option<crate::config::RetryPolicy>,
    faults: Option<crate::worker::fault::FaultPlan>,
    resident: Option<crate::config::Resident>,
}

impl<'g, 's, P: VertexProgram> JobBuilder<'g, 's, P> {
    /// Execution mode.  [`Mode::Auto`] picks IO-Recoded (+XLA per the
    /// [`Xla`] policy) when the program has a combiner and the graph has
    /// been recoded, falling back to IO-Basic.  Note that recoded jobs
    /// address vertices in the recoded ID space — translate sources via
    /// [`LoadedGraph::current_id_of`].
    pub fn mode(mut self, m: Mode) -> Self {
        self.mode = m;
        self
    }

    /// Per-job XLA policy (default: the session's).
    pub fn xla(mut self, x: Xla) -> Self {
        self.xla = x;
        self
    }

    /// Per-job superstep cap (0 = unlimited).
    pub fn max_supersteps(mut self, n: u64) -> Self {
        self.max_supersteps = Some(n);
        self
    }

    /// Enable periodic checkpoints (§3.4).
    pub fn checkpoint(mut self, ck: CheckpointCfg) -> Self {
        self.checkpoint = Some(ck);
        self
    }

    /// Restart from the completed checkpoint taken after superstep `s`
    /// (requires [`Self::checkpoint`] to point at the checkpoint dir).
    pub fn resume(mut self, s: u64) -> Self {
        self.resume = Some(s);
        self
    }

    /// Stall-and-send ablation switch for this job.
    pub fn disable_oms(mut self, d: bool) -> Self {
        self.disable_oms = Some(d);
        self
    }

    /// Local-delivery fast path for this job (default: the session's).
    pub fn local_fastpath(mut self, on: bool) -> Self {
        self.local_fastpath = Some(on);
        self
    }

    /// Per-job tracing: Chrome-trace export on success (to
    /// `TraceConfig.path`, default `<workdir>/trace.json`) and
    /// flight-recorder dumps (`<workdir>/flightrec_<machine>.log`) on
    /// failure.  Default: the session's (`-c trace=true` / `trace_path=`).
    pub fn trace(mut self, t: crate::trace::TraceConfig) -> Self {
        self.trace = Some(t);
        self
    }

    /// Auto-resume policy: on a retryable failure (injected or real I/O
    /// error, transient network fault, first panic at a superstep) with a
    /// durable checkpoint available, [`Self::run`] tears the job down,
    /// reloads the checkpoint, and re-runs — up to `max_retries` times
    /// with exponential backoff.  Default: no retries (fail fast).
    pub fn retry(mut self, p: crate::config::RetryPolicy) -> Self {
        self.retry = Some(p);
        self
    }

    /// Deterministic fault injection (testing/chaos): each spec in `plan`
    /// fires exactly once when the chosen unit reaches the chosen machine +
    /// superstep, surfacing as the corresponding typed error.  Combine
    /// with [`Self::retry`] + [`Self::checkpoint`] to exercise the
    /// recovery path end to end.
    pub fn inject_faults(mut self, plan: crate::worker::fault::FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Adjacency residency for this job (default: the session's, see
    /// [`GraphDBuilder::resident`] / `-c resident=`).  `Resident::Mmap`
    /// makes U_c read adjacency from the mmap'd CSR pair materialized
    /// beside the store — an O(1) zero-copy slice per vertex, page cache
    /// instead of buffered re-reads, still O(|V|/n) *heap*.  Values are
    /// bit-identical to streaming in every mode: the mapped payload is
    /// byte-identical to `se.bin` by construction.
    pub fn resident(mut self, r: crate::config::Resident) -> Self {
        self.resident = Some(r);
        self
    }

    /// Resolve `Auto` mode and the XLA policy without running the job.
    pub fn plan(&self) -> JobPlan {
        let has_combiner = self.program.combiner().is_some();
        let artifacts_present = runtime::artifacts_present(&self.graph.session.artifacts_dir());
        let mode = match self.mode {
            Mode::Auto => {
                if has_combiner && self.graph.recoded.is_some() {
                    Mode::Recoded
                } else {
                    Mode::Basic
                }
            }
            m => m,
        };
        let use_xla = mode == Mode::Recoded
            && match self.xla {
                Xla::On => true,
                Xla::Off => false,
                Xla::Auto => artifacts_present,
            };
        JobPlan {
            mode,
            use_xla,
            artifacts_present,
        }
    }

    /// The paper's "Compute" phase: run the superstep loop to termination
    /// and gather values + metrics (load/preprocess timings from the
    /// [`LoadedGraph`] are folded into the returned metrics).
    pub fn run(self) -> Result<JobResult<P>> {
        let plan = self.plan();
        let stores: &[MachineStore] = match plan.mode {
            Mode::Recoded => self.graph.recoded.as_deref().ok_or_else(|| {
                Error::Config("Mode::Recoded requires LoadedGraph::recode() first".into())
            })?,
            _ => &self.graph.stores,
        };
        let mut cfg = self.graph.session.cfg.clone();
        cfg.mode = plan.mode;
        cfg.use_xla = plan.use_xla;
        if let Some(n) = self.max_supersteps {
            cfg.max_supersteps = n;
        }
        if let Some(d) = self.disable_oms {
            cfg.disable_oms = d;
        }
        if let Some(f) = self.local_fastpath {
            cfg.local_fastpath = f;
        }
        if let Some(t) = self.trace {
            cfg.trace = t;
        }
        if let Some(p) = self.retry {
            cfg.retry = p;
        }
        if let Some(fp) = self.faults {
            cfg.fault = Some(fp);
        }
        if let Some(r) = self.resident {
            cfg.resident = r;
        }
        // A `checkpoint_every` session/`-c` override without an explicit
        // CheckpointCfg checkpoints into the session DFS.
        let checkpoint = match (self.checkpoint, cfg.checkpoint_every) {
            (Some(ck), _) => {
                cfg.checkpoint_every = ck.every;
                Some(ck)
            }
            (None, every) if every > 0 => Some(CheckpointCfg {
                dir: self.graph.session.workdir().join("dfs").join("checkpoints"),
                every,
            }),
            (None, _) => None,
        };
        let eng = Engine::new(self.graph.engine.profile.clone(), cfg)?;
        let policy = eng.cfg.retry;

        // One trace collector for the whole run, shared across attempts:
        // the exported timeline then shows the injected/real fault, the
        // recovery marks, and the replayed supersteps of every retry side
        // by side instead of the final attempt only.
        let tracer = Arc::new(crate::trace::Tracer::new(eng.cfg.trace.clone()));

        // Auto-resume loop (§3.4): each attempt runs under a *fresh* abort
        // latch (a tripped latch and everything registered on it is
        // single-use — see `JobAbort::reset_for_retry`), resuming from the
        // last durable checkpoint of the previous attempt.
        let mut abort = crate::worker::sync::JobAbort::new();
        let mut resume = self.resume;
        let mut recoveries: u64 = 0;
        let mut retried_supersteps: u64 = 0;
        let mut last_panic_step: Option<u64> = None;
        // Open Recovery span over the in-flight retry attempt, closed when
        // that attempt returns (successfully or not).
        let mut recover_span: Option<(crate::trace::UnitTracer, u64)> = None;
        let mut res = loop {
            let hooks = engine_run::RunHooks {
                tracer: Some(tracer.clone()),
                abort: Some(abort.clone()),
            };
            // Transport dispatch: under sim every machine is a thread of
            // this process; under tcp this process runs one machine and
            // the attempt ordinal fences the cluster re-handshake (all
            // processes classify the propagated cause identically, so they
            // retry — and re-join — in lockstep).
            let run = match eng.cfg.transport {
                crate::net::TransportKind::Sim => engine_run::run_job_with_impl(
                    &eng,
                    stores,
                    self.program.clone(),
                    checkpoint.clone(),
                    resume,
                    hooks,
                ),
                crate::net::TransportKind::Tcp => engine_run::run_job_distributed(
                    &eng,
                    stores,
                    self.program.clone(),
                    checkpoint.clone(),
                    resume,
                    hooks,
                    recoveries,
                ),
            };
            if let Some((mut rtr, s)) = recover_span.take() {
                rtr.end(crate::trace::EventKind::Recovery, s);
                rtr.finish();
            }
            match run {
                Ok(res) => break res,
                // Failed checkpointed job: auto-resume if the policy and
                // the failure class allow it; otherwise report the last
                // durable superstep so the caller can recover manually
                // with `.checkpoint(..).resume(s)` — the paper's §3.4
                // restart, reachable from a typed error.
                Err(Error::JobFailed {
                    machine,
                    unit,
                    superstep,
                    cause,
                }) => {
                    let hint = checkpoint
                        .as_ref()
                        .and_then(|ck| crate::ft::resume_hint(&ck.dir));
                    // Retryable: I/O errors and transient network faults
                    // always; a panic only until it repeats at the same
                    // superstep (then it is deterministic program
                    // behaviour, and re-running cannot help).
                    let is_panic = cause.contains("panic");
                    let retryable = crate::worker::fault::retryable_cause(&cause)
                        || (is_panic && last_panic_step != Some(superstep));
                    if is_panic {
                        last_panic_step = Some(superstep);
                    }
                    if retryable && recoveries < u64::from(policy.max_retries) {
                        if let Some(s) = hint {
                            // Exponential backoff: transient causes (a
                            // flaky switch, a briefly-full disk) need time
                            // to clear before the next attempt.
                            let backoff =
                                policy.backoff.saturating_mul(1 << recoveries.min(16) as u32);
                            // analyze:allow(sleep-slicing): inter-attempt backoff — no units are live between attempts, so there is no abort latch left to observe
                            std::thread::sleep(backoff);
                            abort = abort.reset_for_retry();
                            recoveries += 1;
                            retried_supersteps += superstep.saturating_sub(s);
                            let mut rtr = tracer.unit(0, "recover");
                            rtr.begin(crate::trace::EventKind::Recovery, s);
                            recover_span = Some((rtr, s));
                            resume = Some(s);
                            continue;
                        }
                    }
                    let cause = match hint {
                        Some(s) => format!(
                            "{cause}; last durable checkpoint: superstep {s} \
                             (recover with .checkpoint(..).resume({s}))"
                        ),
                        None => cause,
                    };
                    let cause = if recoveries > 0 {
                        format!("{cause}; retries exhausted after {recoveries} recovery attempt(s)")
                    } else {
                        cause
                    };
                    // Flight recorder: the session owns the shared tracer,
                    // so the final failure drains the rings here (the
                    // engine skips it under session hooks).
                    if tracer.enabled() {
                        let _ = tracer.flight_record(&eng.cfg.workdir, &cause);
                    }
                    return Err(Error::JobFailed {
                        machine,
                        unit,
                        superstep,
                        cause,
                    });
                }
                Err(e) => {
                    if tracer.enabled() {
                        let _ = tracer.flight_record(&eng.cfg.workdir, &e.to_string());
                    }
                    return Err(e);
                }
            }
        };
        if tracer.enabled() {
            let path = eng
                .cfg
                .trace
                .path
                .clone()
                .unwrap_or_else(|| eng.cfg.workdir.join("trace.json"));
            tracer.export_chrome(&path)?;
        }
        res.metrics.recoveries = recoveries;
        res.metrics.retried_supersteps = retried_supersteps;
        res.metrics.load_secs = self.graph.load_secs;
        if plan.mode == Mode::Recoded {
            res.metrics.preprocess_secs = self.graph.recode_secs.unwrap_or(0.0);
        }
        Ok(res)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{PageRank, TriangleCount};
    use crate::graph::generator;

    fn wd(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "graphd_session_test_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn builder_defaults_match_paper_constants() {
        let d = wd("defaults");
        let s = GraphD::builder().workdir(&d).build().unwrap();
        assert_eq!(s.profile().machines, 4);
        assert_eq!(s.profile().name, "test");
        assert_eq!(s.config().stream_buf, 64 * 1024); // b = 64 KB
        assert_eq!(s.config().oms_file_cap, 8 * 1024 * 1024); // ℬ = 8 MB
        assert_eq!(s.config().merge_k, 1000); // k = 1000
        assert_eq!(s.config().mode, Mode::Basic);
        assert_eq!(s.config().max_supersteps, 0);
        assert!(s.workdir().exists());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn builder_overrides_and_validation() {
        let d = wd("overrides");
        let s = GraphD::builder()
            .workdir(&d)
            .machines(3)
            .config("mode", "recoded")
            .config("oms_file_cap", "65536")
            .build()
            .unwrap();
        assert_eq!(s.profile().machines, 3);
        assert_eq!(s.config().mode, Mode::Recoded);
        assert_eq!(s.config().oms_file_cap, 65536);
        let _ = std::fs::remove_dir_all(&d);

        let d2 = wd("badcfg");
        assert!(GraphD::builder()
            .workdir(&d2)
            .config("nope", "1")
            .build()
            .is_err());
        let _ = std::fs::remove_dir_all(&d2);
    }

    #[test]
    fn auto_mode_resolution_depends_on_combiner_and_recode() {
        let d = wd("auto");
        let g = generator::uniform(60, 240, false, 9);
        let s = GraphD::builder().workdir(&d).machines(2).build().unwrap();
        let mut lg = s.load(GraphSource::InMemory(&g)).unwrap();

        // Not recoded yet: Auto falls back to Basic even with a combiner.
        let plan = lg.job(Arc::new(PageRank::new(3))).mode(Mode::Auto).plan();
        assert_eq!(plan.mode, Mode::Basic);

        lg.recode().unwrap();
        // Combiner + recoded stores: Auto picks Recoded.
        let plan = lg.job(Arc::new(PageRank::new(3))).mode(Mode::Auto).plan();
        assert_eq!(plan.mode, Mode::Recoded);
        // No combiner (TriangleCount): Auto stays Basic.
        let plan = lg.job(Arc::new(TriangleCount)).mode(Mode::Auto).plan();
        assert_eq!(plan.mode, Mode::Basic);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn xla_policy_follows_artifacts_dir() {
        let d = wd("xla");
        let fake_artifacts = d.join("arts");
        std::fs::create_dir_all(&fake_artifacts).unwrap();
        let g = generator::uniform(40, 160, false, 3);

        let s = GraphD::builder()
            .workdir(d.join("sess"))
            .machines(2)
            .artifacts_dir(&fake_artifacts)
            .build()
            .unwrap();
        let mut lg = s.load(GraphSource::InMemory(&g)).unwrap();
        lg.recode().unwrap();

        // Empty artifacts dir: Auto resolves to no XLA.
        let plan = lg.job(Arc::new(PageRank::new(2))).mode(Mode::Auto).plan();
        assert_eq!(plan.mode, Mode::Recoded);
        assert!(!plan.artifacts_present);
        assert!(!plan.use_xla);

        // Drop in an artifact file: Auto flips on (plan only — running
        // against a fake artifact is a job error on PJRT builds).
        std::fs::write(fake_artifacts.join("pagerank_update.hlo.txt"), "hlo").unwrap();
        let plan = lg.job(Arc::new(PageRank::new(2))).mode(Mode::Auto).plan();
        assert!(plan.artifacts_present);
        assert!(plan.use_xla);
        // Explicit Off wins over present artifacts.
        let plan = lg
            .job(Arc::new(PageRank::new(2)))
            .mode(Mode::Auto)
            .xla(Xla::Off)
            .plan();
        assert!(!plan.use_xla);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn recoded_mode_without_recode_is_a_config_error() {
        let d = wd("norec");
        let g = generator::uniform(30, 90, true, 4);
        let s = GraphD::builder().workdir(&d).machines(2).build().unwrap();
        let lg = s.load(GraphSource::InMemory(&g)).unwrap();
        let err = lg
            .job(Arc::new(PageRank::new(2)))
            .mode(Mode::Recoded)
            .run()
            .unwrap_err();
        assert!(matches!(err, Error::Config(_)));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn checkpoint_every_override_writes_checkpoints() {
        let d = wd("ckevery");
        let g = generator::uniform(80, 400, true, 13);
        let s = GraphD::builder()
            .workdir(&d)
            .machines(2)
            .max_supersteps(4)
            .config("checkpoint_every", "2")
            .build()
            .unwrap();
        let lg = s.load(GraphSource::InMemory(&g)).unwrap();
        lg.run(Arc::new(PageRank::new(4))).unwrap();
        // every=2 over 4 supersteps checkpoints after step 1 (the final
        // step never checkpoints: the job is already done).
        let ckdir = d.join("dfs").join("checkpoints");
        assert_eq!(
            crate::ft::latest_checkpoint(&ckdir, None),
            Some(1),
            "checkpoint_every=2 must checkpoint into the session DFS"
        );
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn generate_source_loads_and_runs() {
        let d = wd("gen");
        let s = GraphD::builder().workdir(&d).machines(2).build().unwrap();
        let lg = s
            .load(GraphSource::Generate(Dataset::BtcS, 0.02))
            .unwrap();
        assert!(lg.stores().iter().map(|st| st.local_vertices()).sum::<usize>() > 0);
        let res = lg
            .job(Arc::new(PageRank::new(2)))
            .max_supersteps(2)
            .run()
            .unwrap();
        assert_eq!(res.supersteps(), 2);
        assert!(res.metrics.load_secs >= 0.0);
        let _ = std::fs::remove_dir_all(&d);
    }
}
