//! Ablation A1 — the `skip(num_items)` streaming function (§3.2).
//!
//! Runs SSSP (sparse frontiers) and reports, per mode, how many adjacency
//! items were read vs skipped and how many random seeks the skips cost.
//! The paper's design goals: sequential bandwidth when dense, few seeks
//! when sparse, worst case ≤ one full S^E scan per superstep.

use graphd::baselines::Algo;
use graphd::bench::{run_graphd, scale_from_env, sssp_source, use_xla_from_env};
use graphd::config::ClusterProfile;
use graphd::graph::generator::Dataset;
use graphd::metrics::{Cell, Table};

fn main() {
    let scale = scale_from_env();
    let mut t = Table::new(
        &format!("Ablation — skip() effectiveness on SSSP (scale {scale})"),
        &["items read", "items skipped", "seeks", "compute"],
    );
    for ds in [Dataset::BtcS, Dataset::WebUkS] {
        let g = ds.generate_scaled(scale).with_unit_weights();
        let algo = Algo::Sssp {
            source: sssp_source(&g),
        };
        let profile = ClusterProfile::wpc();
        let gd = run_graphd(
            &format!("abl_skip_{}", ds.name()),
            &g,
            algo,
            &profile,
            use_xla_from_env(),
        )
        .expect("run");
        for (mode, m, secs) in [
            ("IO-Basic", &gd.basic_metrics, gd.basic_compute),
            ("IO-Recoded", &gd.recoded_metrics, gd.recoded_compute),
        ] {
            let (mut read, mut skipped, mut seeks) = (0u64, 0u64, 0u64);
            for mm in &m.machines {
                for s in &mm.steps {
                    read += s.edge_items_read;
                    skipped += s.edge_items_skipped;
                    seeks += s.seeks;
                }
            }
            t.row(
                &format!("{} {}", ds.name(), mode),
                vec![
                    Cell::Text(read.to_string()),
                    Cell::Text(skipped.to_string()),
                    Cell::Text(seeks.to_string()),
                    Cell::Secs(secs),
                ],
            );
        }
    }
    println!("{}", t.render());
    println!("expectation: skipped >> read on SSSP; seeks << skipped items");
}
