//! Resident-store throughput — `-c resident=stream` vs `-c resident=mmap`
//! over the same workload, in both IO-Basic and IO-Recoded modes.
//!
//! The streaming path re-reads `se.bin` through `EdgeStreamCursor` (a
//! buffered sequential scan charged against the simulated disk) every
//! superstep; the resident path decodes the same adjacency items as O(1)
//! slices of the mmap'd CSR pair (`csr_offsets` / `csr_edges`, see
//! docs/FORMATS.md).  Both paths decode byte-identical edge payloads, so
//! the run asserts:
//!
//! 1. **Bit-identical values** stream vs mmap, for PageRank and SSSP
//!    (on top of the basic-vs-recoded cross-check `run_graphd_cfg`
//!    already performs per run).
//! 2. **Residency accounting**: the mmap runs decode every adjacency item
//!    from the mapping (`edge_items_mapped == edge_items_read`, > 0); the
//!    stream runs report `edge_items_mapped == 0`.
//! 3. **n = 1 wire silence unchanged**: `net_wire_bytes == 0` with the
//!    local fast path on, exactly as in stream mode — residency must not
//!    perturb message routing.
//!
//! Env: `GRAPHD_SMOKE=1` shrinks the workload; `GRAPHD_XLA=0` forces the
//! scalar kernels; `GRAPHD_BENCH_JSON=path` writes the numbers as the
//! `"resident"` section of the bench JSON.

use graphd::baselines::Algo;
use graphd::bench::{self, check_equivalent, GraphDRuns};
use graphd::config::ClusterProfile;
use graphd::graph::generator;
use graphd::metrics::JobMetrics;

/// Adjacency items decoded from the mmap'd CSR across all machines/steps.
fn mapped_items(m: &JobMetrics) -> u64 {
    m.machines
        .iter()
        .flat_map(|mm| mm.steps.iter())
        .map(|s| s.edge_items_mapped)
        .sum()
}

/// Adjacency items decoded in total (stream + mapped), for the ratio line.
fn read_items(m: &JobMetrics) -> u64 {
    m.machines
        .iter()
        .flat_map(|mm| mm.steps.iter())
        .map(|s| s.edge_items_read)
        .sum()
}

fn report(label: &str, r: &GraphDRuns) {
    println!(
        "{label:<14} basic {:>7.3}s  recoded {:>7.3}s  mapped {:>9}/{:<9} items  wire {:>6} B",
        r.basic_compute,
        r.recoded_compute,
        mapped_items(&r.recoded_metrics),
        read_items(&r.recoded_metrics),
        r.recoded_metrics.net_wire_bytes,
    );
}

fn main() {
    let smoke = bench::smoke_from_env();
    println!(
        "== Resident store: stream vs mmap'd CSR =={}",
        if smoke { "  (smoke)" } else { "" }
    );

    let (nv, ne) = if smoke { (4_000, 24_000) } else { (40_000, 240_000) };
    let g = generator::uniform(nv, ne, true, 17);
    let profile = ClusterProfile::test(1);
    let use_xla = bench::use_xla_from_env();
    let mmap_cfg: Vec<(String, String)> = vec![("resident".into(), "mmap".into())];

    let mut failed = false;
    let mut sections = Vec::new();
    let combos = [
        ("pagerank", Algo::PageRank { supersteps: 5 }),
        ("sssp", Algo::Sssp { source: bench::sssp_source(&g) }),
    ];
    for (name, algo) in combos {
        let stream = bench::run_graphd_cfg(&format!("res_stream_{name}"), &g, algo, &profile, use_xla, &[])
            .expect("stream run");
        let mmap = bench::run_graphd_cfg(&format!("res_mmap_{name}"), &g, algo, &profile, use_xla, &mmap_cfg)
            .expect("mmap run");

        println!("-- {name}, n=1, uniform graph ({nv} vertices, {ne} edges) --");
        report("stream", &stream);
        report("mmap", &mmap);
        let speedup = stream.recoded_compute / mmap.recoded_compute.max(1e-9);
        println!("{:<14} recoded compute {speedup:>6.2}x", "speedup");

        if let Err(e) = check_equivalent(&stream.values, &mmap.values, algo) {
            eprintln!("FAIL: {name} stream vs mmap values diverge: {e}");
            failed = true;
        }
        for (mode, m) in [("basic", &mmap.basic_metrics), ("recoded", &mmap.recoded_metrics)] {
            let mapped = mapped_items(m);
            let read = read_items(m);
            if mapped == 0 || mapped != read {
                eprintln!(
                    "FAIL: {name} {mode} mmap run must decode all {read} adjacency items \
                     from the mapping (got {mapped})"
                );
                failed = true;
            }
        }
        if mapped_items(&stream.recoded_metrics) != 0 {
            eprintln!("FAIL: {name} stream run reported mapped items");
            failed = true;
        }
        if mmap.recoded_metrics.net_wire_bytes != 0 || mmap.basic_metrics.net_wire_bytes != 0 {
            eprintln!(
                "FAIL: {name} n=1 mmap run must keep the switch silent (basic {} B, recoded {} B)",
                mmap.basic_metrics.net_wire_bytes, mmap.recoded_metrics.net_wire_bytes
            );
            failed = true;
        }

        sections.push(format!(
            "\"{name}_stream_basic_secs\": {:.4}, \
             \"{name}_stream_recoded_secs\": {:.4}, \
             \"{name}_mmap_basic_secs\": {:.4}, \
             \"{name}_mmap_recoded_secs\": {:.4}, \
             \"{name}_recoded_speedup\": {speedup:.3}, \
             \"{name}_mapped_items\": {}",
            stream.basic_compute,
            stream.recoded_compute,
            mmap.basic_compute,
            mmap.recoded_compute,
            mapped_items(&mmap.recoded_metrics),
        ));
    }

    if let Some(path) = bench::bench_json_path() {
        let body = format!("{{{}}}", sections.join(", "));
        bench::bench_json_merge(&path, "resident", &body).expect("bench json");
        eprintln!("wrote {path} (section: resident)");
    }
    if failed {
        std::process::exit(1);
    }
}
