//! Table 7 — SSSP on W^PC (paper analog; see README.md experiment index).
//!
//! Env: GRAPHD_SCALE (default 1.0), GRAPHD_SYSTEMS filter, GRAPHD_XLA=0.

use graphd::baselines::Algo;
use graphd::bench::{render_table, scale_from_env};
use graphd::config::ClusterProfile;
use graphd::graph::generator::Dataset;

fn main() {
    let profile = ClusterProfile::wpc();
    let combos = [(Dataset::BtcS, Algo::Sssp { source: 0 }), (Dataset::FriendsterS, Algo::Sssp { source: 0 }), (Dataset::WebUkS, Algo::Sssp { source: 0 }), (Dataset::TwitterS, Algo::Sssp { source: 0 })];
    match render_table("Table 7 — SSSP on W^PC", &combos, &profile, scale_from_env()) {
        Ok(s) => println!("{s}"),
        Err(e) => {
            eprintln!("bench failed: {e}");
            std::process::exit(1);
        }
    }
}
