//! Ablation A3 — OMS file cap ℬ sweep (§3.3.1).
//!
//! Small ℬ = fine-grained files (less sender stalling on the tail file,
//! but many small network batches); large ℬ = efficient batches but
//! coarse-grained overlap.  The paper picks 8 MB; at our scale the
//! interesting regime is correspondingly smaller.

use graphd::algos::PageRank;
use graphd::bench::scale_from_env;
use graphd::config::ClusterProfile;
use graphd::graph::generator::Dataset;
use graphd::metrics::{Cell, Table};
use graphd::util::timer::timed;
use graphd::{GraphD, GraphSource};
use std::sync::Arc;

fn main() {
    let scale = scale_from_env();
    let g = Dataset::TwitterS.generate_scaled(scale);
    let steps = 10u64;
    let profile = ClusterProfile::wpc();

    let mut t = Table::new(
        &format!("Ablation — OMS file cap ℬ sweep, PageRank twitter-s (scale {scale})"),
        &["Compute", "OMS files"],
    );
    for cap in [64 * 1024, 256 * 1024, 1024 * 1024, 8 * 1024 * 1024] {
        let wd = std::env::temp_dir().join(format!("graphd_abl_b{}_{}", cap, std::process::id()));
        let _ = std::fs::remove_dir_all(&wd);
        let session = GraphD::builder()
            .profile(profile.clone())
            .workdir(&wd)
            .max_supersteps(steps)
            .oms_file_cap(cap)
            .build()
            .expect("session");
        let graph = session
            .load(GraphSource::InMemorySparse(&g, 4242))
            .expect("load");
        let (secs, res) = timed(|| graph.run(Arc::new(PageRank::new(steps))));
        let res = res.expect("run");
        let files: u64 = res
            .metrics
            .machines
            .iter()
            .flat_map(|m| m.steps.iter())
            .map(|s| s.oms_files)
            .sum();
        t.row(
            &graphd::util::human_bytes(cap as u64),
            vec![Cell::Secs(secs), Cell::Text(files.to_string())],
        );
        let _ = std::fs::remove_dir_all(&wd);
    }
    println!("{}", t.render());
}
