//! Spine throughput — the zero-copy message spine vs the pre-refactor
//! path, in two measurements:
//!
//! 1. **Combining kernel**: the same digest-heavy PageRank-sum message
//!    files pushed through (a) a faithful replica of the legacy kernel —
//!    `dyn` combiner dispatch per record, a fresh allocation per file read
//!    and per output batch — and (b) the monomorphized, pooled
//!    `combine_in_memory`.  Reported as msgs/sec.
//!
//! 2. **Engine, digest-heavy PageRank at n = 1**: every message is local,
//!    so the local-delivery fast path must drive `Switch::total_bytes` to
//!    **zero** and beat the pre-refactor routing (`local_fastpath(false)`:
//!    every batch through OMS files + the simulated switch) by ≥ 2×
//!    msgs/sec.  The bench exits non-zero otherwise.
//!
//! 3. **Engine, IO-Basic at n = 1** (same workload, no recoding): the
//!    local spill lane must likewise drive wire bytes to zero — local
//!    messages go straight from U_c's sorted spills into the S^I merge,
//!    skipping OMS files, pre-send combining, and the switch.  Reported
//!    as an off/on comparison; wire == 0 is asserted, the speedup is
//!    informational (the off path's merge-sort work varies by machine).
//!
//! Env: `GRAPHD_SMOKE=1` shrinks the workload (the `make bench-smoke`
//! quick mode); `GRAPHD_BENCH_JSON=path` writes the numbers as the
//! `"spine"` and `"basic"` sections of the bench JSON (BENCH_PR4.json).

use graphd::api::SumF32;
use graphd::config::{ClusterProfile, Mode};
use graphd::graph::generator;
use graphd::msg::{encode_msg, msg_rec_size, rec_payload, rec_target, BufPool};
use graphd::util::bitset::BitSet;
use graphd::util::rng::Rng;
use graphd::util::timer::timed;
use graphd::worker::units::{combine_in_memory, TakenFile};
use graphd::{GraphD, GraphSource};
use std::path::PathBuf;
use std::sync::Arc;

// ----------------------------------------------------------------- kernel

/// The legacy combiner shape: object-safe, dispatched per record.
trait DynCombiner: Sync {
    fn combine(&self, acc: &mut f32, m: &f32);
    fn identity(&self) -> f32;
}

struct DynSum;
impl DynCombiner for DynSum {
    fn combine(&self, acc: &mut f32, m: &f32) {
        *acc += *m;
    }
    fn identity(&self) -> f32 {
        0.0
    }
}

/// Faithful replica of the pre-refactor `combine_in_memory`: virtual call
/// per record, `std::fs::read` allocation per file, fresh output vector.
fn legacy_combine(
    files: &[TakenFile],
    combiner: &dyn DynCombiner,
    n: usize,
    a_s: &mut [f32],
    touched: &mut Vec<u32>,
    bits: &mut BitSet,
) -> Vec<u8> {
    let rec_size = msg_rec_size::<f32>();
    for (_, path, _) in files {
        let data = std::fs::read(path).expect("read");
        for rec in data.chunks_exact(rec_size) {
            let target = rec_target(rec);
            let pos = target as usize / n;
            let m = rec_payload::<f32>(rec);
            if bits.get(pos) {
                combiner.combine(&mut a_s[pos], &m);
            } else {
                a_s[pos] = m;
                bits.set(pos, true);
                touched.push(target);
            }
        }
    }
    touched.sort_unstable();
    let mut out = Vec::with_capacity(touched.len() * rec_size);
    for &t in touched.iter() {
        let pos = t as usize / n;
        encode_msg(t, &a_s[pos], &mut out);
        a_s[pos] = combiner.identity();
        bits.set(pos, false);
    }
    touched.clear();
    out
}

fn write_message_files(dir: &PathBuf, nmsgs: usize, local: usize, n: usize) -> Vec<TakenFile> {
    std::fs::create_dir_all(dir).expect("mkdir");
    let mut rng = Rng::new(7);
    let nfiles = 16;
    let per = nmsgs / nfiles;
    let mut files = Vec::new();
    for i in 0..nfiles {
        let mut buf = Vec::with_capacity(per * 8);
        for _ in 0..per {
            let pos = rng.below(local as u64) as usize;
            encode_msg((pos * n) as u32, &(rng.below(1000) as f32), &mut buf);
        }
        let p = dir.join(format!("f{i}"));
        std::fs::write(&p, &buf).expect("write");
        files.push((i as u64, p, buf.len() as u64));
    }
    files
}

fn kernel_bench(smoke: bool) -> (f64, f64) {
    let nmsgs = if smoke { 400_000 } else { 2_000_000 };
    let local = 20_000usize;
    let n = 4usize;
    let iters = 5;
    let dir = std::env::temp_dir().join(format!("graphd_spine_bench_{}", std::process::id()));
    let files = write_message_files(&dir, nmsgs, local, n);
    let total = (iters * nmsgs) as f64;

    let comb = SumF32;
    let dyn_comb: &dyn DynCombiner = &DynSum;
    let mut a_s = vec![0.0f32; local + 1];
    let mut touched: Vec<u32> = Vec::new();
    let mut bits = BitSet::new(local + 1);
    let pool = BufPool::new(8);

    // Warm both paths once (page cache, pool shelf), then measure.
    let _ = legacy_combine(&files, dyn_comb, n, &mut a_s, &mut touched, &mut bits);
    let _ = combine_in_memory::<f32, SumF32>(
        &files, &comb, n, &mut a_s, &mut touched, &mut bits, &pool,
    )
    .expect("combine");

    let (legacy_secs, ()) = timed(|| {
        for _ in 0..iters {
            let out = legacy_combine(&files, dyn_comb, n, &mut a_s, &mut touched, &mut bits);
            assert!(!out.is_empty());
        }
    });
    let (mono_secs, ()) = timed(|| {
        for _ in 0..iters {
            let out = combine_in_memory::<f32, SumF32>(
                &files, &comb, n, &mut a_s, &mut touched, &mut bits, &pool,
            )
            .expect("combine");
            assert!(!out.is_empty());
            pool.put(out); // the receiver would recycle the wire batch
        }
    });
    let _ = std::fs::remove_dir_all(&dir);
    (total / legacy_secs.max(1e-9), total / mono_secs.max(1e-9))
}

// ----------------------------------------------------------------- engine

struct EngineRun {
    msgs_per_sec: f64,
    wire_bytes: u64,
    local_bytes: u64,
    pool_hit_rate: f64,
}

fn engine_run(g: &graphd::graph::Graph, steps: u64, mode: Mode, fastpath: bool) -> EngineRun {
    // One machine on a slow shared switch: digest-heavy PageRank where the
    // pre-refactor path pays simulated wire time for every local batch.
    let mut profile = ClusterProfile::test(1);
    profile.net_bytes_per_sec = 16.0 * 1024.0 * 1024.0;
    profile.latency_us = 300;
    let session = GraphD::builder()
        .profile(profile)
        .max_supersteps(steps)
        .build()
        .expect("session");
    let mut graph = session.load(GraphSource::InMemory(g)).expect("load");
    if mode == Mode::Recoded {
        graph.recode().expect("recode");
    }
    let res = graph
        .job(Arc::new(graphd::algos::PageRank::new(steps)))
        .mode(mode)
        .local_fastpath(fastpath)
        .run()
        .expect("run");
    let out = EngineRun {
        msgs_per_sec: res.metrics.total_msgs() as f64 / res.metrics.compute_secs.max(1e-9),
        wire_bytes: res.metrics.net_wire_bytes,
        local_bytes: res.metrics.net_local_bytes,
        pool_hit_rate: res.metrics.pool.hit_rate(),
    };
    let _ = std::fs::remove_dir_all(session.workdir());
    out
}

fn main() {
    let smoke = graphd::bench::smoke_from_env();
    println!(
        "== Spine throughput: monomorphized + pooled + local fast path vs legacy =={}",
        if smoke { "  (smoke)" } else { "" }
    );

    let (legacy_mps, mono_mps) = kernel_bench(smoke);
    let kernel_speedup = mono_mps / legacy_mps.max(1e-9);
    println!("-- combining kernel (digest-heavy PageRank-sum files) --");
    println!("legacy (dyn dispatch, alloc/batch)   {legacy_mps:>12.0} msgs/s");
    println!("monomorphized + pooled               {mono_mps:>12.0} msgs/s");
    println!("kernel speedup                       {kernel_speedup:>12.2}x");

    let (nv, ne) = if smoke { (4_000, 24_000) } else { (20_000, 120_000) };
    let g = generator::uniform(nv, ne, true, 13);
    let steps = 5;
    let off = engine_run(&g, steps, Mode::Recoded, false);
    let on = engine_run(&g, steps, Mode::Recoded, true);
    let engine_speedup = on.msgs_per_sec / off.msgs_per_sec.max(1e-9);
    println!("-- engine, digest-heavy PageRank, n=1, IO-Recoded (all traffic local) --");
    println!(
        "fast path off  {:>12.0} msgs/s   wire {:>10} B   local {:>10} B",
        off.msgs_per_sec, off.wire_bytes, off.local_bytes
    );
    println!(
        "fast path on   {:>12.0} msgs/s   wire {:>10} B   local {:>10} B",
        on.msgs_per_sec, on.wire_bytes, on.local_bytes
    );
    println!(
        "engine speedup {engine_speedup:>12.2}x   pool hit rate {:.1}%",
        on.pool_hit_rate * 100.0
    );

    // IO-Basic off/on: the spill lane vs the full OMS + switch route.
    let boff = engine_run(&g, steps, Mode::Basic, false);
    let bon = engine_run(&g, steps, Mode::Basic, true);
    let basic_speedup = bon.msgs_per_sec / boff.msgs_per_sec.max(1e-9);
    println!("-- engine, same workload, n=1, IO-Basic (local spill lane) --");
    println!(
        "spill lane off {:>12.0} msgs/s   wire {:>10} B   local {:>10} B",
        boff.msgs_per_sec, boff.wire_bytes, boff.local_bytes
    );
    println!(
        "spill lane on  {:>12.0} msgs/s   wire {:>10} B   local {:>10} B",
        bon.msgs_per_sec, bon.wire_bytes, bon.local_bytes
    );
    println!("basic speedup  {basic_speedup:>12.2}x");

    if let Some(path) = graphd::bench::bench_json_path() {
        let body = format!(
            "{{\"kernel_legacy_msgs_per_sec\": {legacy_mps:.0}, \
               \"kernel_mono_msgs_per_sec\": {mono_mps:.0}, \
               \"kernel_speedup\": {kernel_speedup:.3}, \
               \"engine_fastpath_off_msgs_per_sec\": {:.0}, \
               \"engine_fastpath_on_msgs_per_sec\": {:.0}, \
               \"engine_speedup\": {engine_speedup:.3}, \
               \"wire_bytes_fastpath_off\": {}, \
               \"wire_bytes_fastpath_on\": {}, \
               \"local_bytes_fastpath_on\": {}, \
               \"pool_hit_rate\": {:.4}}}",
            off.msgs_per_sec,
            on.msgs_per_sec,
            off.wire_bytes,
            on.wire_bytes,
            on.local_bytes,
            on.pool_hit_rate,
        );
        graphd::bench::bench_json_write(&path, "spine", &body).expect("bench json");
        let basic_body = format!(
            "{{\"engine_spill_off_msgs_per_sec\": {:.0}, \
               \"engine_spill_on_msgs_per_sec\": {:.0}, \
               \"basic_speedup\": {basic_speedup:.3}, \
               \"wire_bytes_spill_off\": {}, \
               \"wire_bytes_spill_on\": {}, \
               \"local_bytes_spill_on\": {}}}",
            boff.msgs_per_sec, bon.msgs_per_sec, boff.wire_bytes, bon.wire_bytes, bon.local_bytes,
        );
        graphd::bench::bench_json_merge(&path, "basic", &basic_body).expect("bench json");
        eprintln!("wrote {path} (sections: spine, basic)");
    }

    let mut failed = false;
    if on.wire_bytes != 0 {
        eprintln!(
            "FAIL: n=1 fast-path run must push 0 bytes through the switch (got {})",
            on.wire_bytes
        );
        failed = true;
    }
    if bon.wire_bytes != 0 {
        eprintln!(
            "FAIL: n=1 IO-Basic spill-lane run must push 0 bytes through the switch (got {})",
            bon.wire_bytes
        );
        failed = true;
    }
    if engine_speedup < 2.0 {
        eprintln!(
            "FAIL: fast-path engine must be >= 2x the pre-refactor path (got {engine_speedup:.2}x)"
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
}
