//! Ablation A5 — XLA block update vs scalar Rust on the recoded hot path.
//!
//! Measures IO-Recoded PageRank compute time with the AOT Pallas kernels
//! (PJRT CPU) against the bit-identical scalar fallback, plus a pure
//! kernel microbenchmark (block update throughput), isolating Layer-1
//! cost from the streaming/network-dominated end-to-end time.

use graphd::baselines::Algo;
use graphd::bench::{run_graphd, scale_from_env};
use graphd::config::ClusterProfile;
use graphd::graph::generator::Dataset;
use graphd::metrics::{Cell, Table};
use graphd::runtime::{KernelSet, BLOCK};
use std::time::Instant;

fn main() {
    let scale = scale_from_env();
    let g = Dataset::TwitterS.generate_scaled(scale);
    let algo = Algo::PageRank { supersteps: 10 };
    let profile = ClusterProfile::wpc();

    let mut t = Table::new(
        &format!("Ablation — XLA block update vs scalar (scale {scale})"),
        &["IO-Recoded compute"],
    );
    for (label, use_xla) in [("XLA (PJRT)", true), ("scalar Rust", false)] {
        match run_graphd(&format!("abl_xla_{use_xla}"), &g, algo, &profile, use_xla) {
            Ok(gd) => t.row(label, vec![Cell::Secs(gd.recoded_compute)]),
            Err(e) => {
                eprintln!("{label}: {e}");
                t.row(label, vec![Cell::Text(format!("failed: {e}"))]);
            }
        }
    }
    println!("{}", t.render());

    // Microbenchmark: raw block-update throughput (vertices/sec).
    let dir = KernelSet::default_dir();
    let kernels: Vec<(&str, KernelSet)> = if dir.join("pagerank_update.hlo.txt").exists() {
        vec![
            ("XLA", KernelSet::load(&dir).expect("load artifacts")),
            ("native", KernelSet::native_only()),
        ]
    } else {
        eprintln!("artifacts missing — microbench runs native only");
        vec![("native", KernelSet::native_only())]
    };
    let n = 4 * BLOCK;
    let sums: Vec<f32> = (0..n).map(|i| (i % 97) as f32 / 97.0).collect();
    let degs: Vec<f32> = (0..n).map(|i| (i % 9) as f32).collect();
    let mut t2 = Table::new(
        "L1 microbench — pagerank_update over 64Ki vertices",
        &["per call", "Mvert/s"],
    );
    for (label, ks) in &kernels {
        // warmup
        let _ = ks.pagerank_update(&sums, &degs, 1e-6).unwrap();
        let reps = 50;
        let t0 = Instant::now();
        for _ in 0..reps {
            let _ = ks.pagerank_update(&sums, &degs, 1e-6).unwrap();
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        t2.row(
            label,
            vec![
                Cell::Text(format!("{:.3} ms", per * 1e3)),
                Cell::Text(format!("{:.1}", n as f64 / per / 1e6)),
            ],
        );
    }
    println!("{}", t2.render());
}
