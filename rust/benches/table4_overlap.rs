//! Table 4 — message generation vs message transmission (PageRank).
//!
//! For each data-cluster combination, reports M-Send (U_s transmission
//! time) and M-Gene (U_c vertex-centric computation time, which includes
//! all local disk streaming) summed over machine 0's supersteps.  The
//! paper's claim: on a commodity switch M-Gene ≪ M-Send, i.e. computation
//! and disk I/O hide entirely inside communication.
//!
//! Env: GRAPHD_SCALE, GRAPHD_XLA=0.

use graphd::baselines::Algo;
use graphd::bench::{run_graphd, scale_from_env, use_xla_from_env};
use graphd::config::ClusterProfile;
use graphd::graph::generator::Dataset;
use graphd::metrics::{Cell, Table};

fn main() {
    let scale = scale_from_env();
    let combos = [
        (Dataset::WebUkS, 10u64),
        (Dataset::ClueWebS, 5),
        (Dataset::TwitterS, 10),
    ];
    let mut t = Table::new(
        &format!("Table 4 — M-Send vs M-Gene, PageRank (scale {scale})"),
        &["mode", "M-Send", "M-Gene"],
    );
    for profile in [ClusterProfile::wpc(), ClusterProfile::whigh()] {
        for (ds, steps) in combos {
            let g = ds.generate_scaled(scale);
            let algo = Algo::PageRank { supersteps: steps };
            let tag = format!("t4_{}_{}", ds.name(), profile.name);
            match run_graphd(&tag, &g, algo, &profile, use_xla_from_env()) {
                Ok(gd) => {
                    let (bg, bs) = gd.basic_metrics.m_gene_m_send();
                    let (rg, rs) = gd.recoded_metrics.m_gene_m_send();
                    t.row(
                        &format!("{} {}", profile.name, ds.name()),
                        vec![Cell::Text("IO-Basic".into()), Cell::Secs(bs), Cell::Secs(bg)],
                    );
                    t.row(
                        "",
                        vec![Cell::Text("IO-Recoded".into()), Cell::Secs(rs), Cell::Secs(rg)],
                    );
                }
                Err(e) => {
                    eprintln!("{} {} failed: {e}", profile.name, ds.name());
                    std::process::exit(1);
                }
            }
        }
    }
    println!("{}", t.render());
}
