//! Ablation A2 — OMS buffering vs stall-and-send (§3.3.1 "Design
//! Philosophy").
//!
//! `disable_oms=true` reproduces the design the paper argues against:
//! outgoing messages are buffered in memory and U_c *stalls* to transmit
//! whenever the buffer fills, serializing computation and communication.
//! With OMSs, appending to disk never blocks on the network and U_s
//! overlaps transmission with U_c's next superstep.

use graphd::algos::PageRank;
use graphd::baselines::Algo;
use graphd::bench::{run_graphd, scale_from_env, use_xla_from_env};
use graphd::config::ClusterProfile;
use graphd::graph::generator::Dataset;
use graphd::metrics::{Cell, Table};
use graphd::util::timer::timed;
use graphd::{GraphD, GraphSource};
use std::sync::Arc;

fn main() {
    let scale = scale_from_env();
    let ds = Dataset::WebUkS;
    let g = ds.generate_scaled(scale);
    let steps = 10u64;
    let profile = ClusterProfile::wpc();

    // with OMS (normal IO-Basic path)
    let gd = run_graphd(
        "abl_oms_on",
        &g,
        Algo::PageRank { supersteps: steps },
        &profile,
        use_xla_from_env(),
    )
    .expect("run");

    // without OMS: stall-and-send
    let wd = std::env::temp_dir().join(format!("graphd_abl_oms_off_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&wd);
    let session = GraphD::builder()
        .profile(profile.clone())
        .workdir(&wd)
        .max_supersteps(steps)
        .build()
        .expect("session");
    let graph = session
        .load(GraphSource::InMemorySparse(&g, 4242))
        .expect("load");
    let (stall_secs, res) = timed(|| {
        graph
            .job(Arc::new(PageRank::new(steps)))
            .disable_oms(true)
            .run()
    });
    res.expect("stall run");
    let _ = std::fs::remove_dir_all(&wd);

    let mut t = Table::new(
        &format!(
            "Ablation — OMS overlap vs stall-and-send, PageRank {} (scale {scale})",
            ds.name()
        ),
        &["Compute"],
    );
    t.row("OMS (overlap)", vec![Cell::Secs(gd.basic_compute)]);
    t.row("no OMS (stall)", vec![Cell::Secs(stall_secs)]);
    println!("{}", t.render());
    println!(
        "speedup from overlapping: {:.2}x",
        stall_secs / gd.basic_compute.max(1e-9)
    );
}
