//! Table 1: dataset statistics of the five scaled paper analogs.
//!
//! Regenerates the |V| / |E| / AVG-deg / MAX-deg rows (at the simulated
//! scale; ratios — degree shape, directedness — match the originals).

use graphd::bench::scale_from_env;
use graphd::graph::generator::Dataset;
use graphd::metrics::{Cell, Table};

fn main() {
    let scale = scale_from_env();
    let mut t = Table::new(
        &format!("Table 1 — graph datasets (scale {scale})"),
        &["Type", "|V|", "|E|", "AVG Deg", "MAX Deg"],
    );
    for ds in Dataset::all() {
        let g = ds.generate_scaled(scale);
        let s = g.stats();
        t.row(
            ds.name(),
            vec![
                Cell::Text(if s.directed { "directed" } else { "undirected" }.into()),
                Cell::Text(s.nv.to_string()),
                Cell::Text(s.ne.to_string()),
                Cell::Text(format!("{:.2}", s.avg_deg)),
                Cell::Text(s.max_deg.to_string()),
            ],
        );
    }
    println!("{}", t.render());
}
