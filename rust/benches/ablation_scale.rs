//! Ablation A6 — machine-count scaling on a shared switch.
//!
//! The paper's §1 argument for *small* clusters: with n machines there are
//! n·(n−1) communication pairs contending for one switch, so adding
//! machines stops helping once the network saturates — while per-machine
//! memory (O(|V|/n)) and disk parallelism keep improving.  This sweep runs
//! IO-Recoded PageRank on webuk-s with n ∈ {2,4,8,16} on the W^PC switch.

use graphd::baselines::Algo;
use graphd::bench::{run_graphd, scale_from_env, use_xla_from_env};
use graphd::config::ClusterProfile;
use graphd::graph::generator::Dataset;
use graphd::metrics::{Cell, Table};
use graphd::util::human_bytes;

fn main() {
    let scale = scale_from_env();
    let g = Dataset::WebUkS.generate_scaled(scale);
    let algo = Algo::PageRank { supersteps: 10 };

    let mut t = Table::new(
        &format!("Ablation — machines sweep, IO-Recoded PageRank webuk-s (scale {scale})"),
        &["Load", "Compute", "peak state/machine"],
    );
    for n in [2usize, 4, 8, 16] {
        let mut profile = ClusterProfile::wpc();
        profile.machines = n;
        match run_graphd(&format!("abl_scale_{n}"), &g, algo, &profile, use_xla_from_env()) {
            Ok(gd) => t.row(
                &format!("n = {n:>2}"),
                vec![
                    Cell::Secs(gd.basic_load),
                    Cell::Secs(gd.recoded_compute),
                    Cell::Text(human_bytes(gd.recoded_metrics.peak_state_bytes())),
                ],
            ),
            Err(e) => t.row(&format!("n = {n:>2}"), vec![Cell::Text(format!("{e}")), Cell::NA, Cell::NA]),
        }
    }
    println!("{}", t.render());
    println!(
        "expectation: per-machine state shrinks ~1/n; compute flattens once the\n\
         shared switch saturates (the paper's case against big clusters, §1)"
    );
}
