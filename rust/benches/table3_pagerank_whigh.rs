//! Table 3 — PageRank on W^high (paper analog; see README.md experiment index).
//!
//! Env: GRAPHD_SCALE (default 1.0), GRAPHD_SYSTEMS filter, GRAPHD_XLA=0.

use graphd::baselines::Algo;
use graphd::bench::{render_table, scale_from_env};
use graphd::config::ClusterProfile;
use graphd::graph::generator::Dataset;

fn main() {
    let profile = ClusterProfile::whigh();
    let combos = [(Dataset::WebUkS, Algo::PageRank { supersteps: 10 }), (Dataset::ClueWebS, Algo::PageRank { supersteps: 5 }), (Dataset::TwitterS, Algo::PageRank { supersteps: 10 })];
    match render_table("Table 3 — PageRank on W^high", &combos, &profile, scale_from_env()) {
        Ok(s) => println!("{s}"),
        Err(e) => {
            eprintln!("bench failed: {e}");
            std::process::exit(1);
        }
    }
}
