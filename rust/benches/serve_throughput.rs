//! Serve throughput — batched k-lane queries vs sequential k=1.
//!
//! The serving argument in one number: a batch of point-to-point queries
//! packed into one k-lane multi-source run streams `S^E` once per
//! superstep for the whole batch, where k=1 sequential serving pays that
//! edge-stream pass per query.  This bench submits the same
//! `query_set`-generated workload both ways on a disk-throttled W^PC-style
//! profile and reports queries/sec; the batched run should win by ≥ 3×.
//!
//! Env: GRAPHD_SCALE (default 1.0) scales the dataset; GRAPHD_QUERIES
//! overrides the workload size (default 24).

use graphd::config::ClusterProfile;
use graphd::graph::generator::{self, Dataset};
use graphd::metrics::ServeMetrics;
use graphd::serve::ServeConfig;
use graphd::{GraphD, GraphSource};

fn serve_workload(
    g: &graphd::graph::Graph,
    profile: &ClusterProfile,
    lanes: usize,
    pairs: &[(u32, u32)],
) -> graphd::Result<ServeMetrics> {
    let session = GraphD::builder().profile(profile.clone()).build()?;
    let mut graph = session.load(GraphSource::InMemory(g))?;
    graph.recode()?;
    let mut server = graph.serve(ServeConfig::default().lanes(lanes))?;
    server.submit_pairs(pairs);
    let results = server.run_pending()?;
    assert_eq!(results.len(), pairs.len(), "every query must be answered");
    let metrics = server.metrics().clone();
    let _ = std::fs::remove_dir_all(session.workdir());
    Ok(metrics)
}

fn main() {
    let scale = graphd::bench::scale_from_env();
    let nq: usize = std::env::var("GRAPHD_QUERIES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);

    // W^PC-shaped profile at test size: slow shared switch + throttled
    // disks, so edge-stream I/O dominates — the regime the paper serves in.
    let mut profile = ClusterProfile::wpc();
    profile.machines = 4;

    let g = Dataset::WebUkS.generate_scaled(scale * 0.2);
    let pairs = generator::query_set(g.num_vertices(), nq, 7);
    eprintln!(
        "serve bench: webuk-s |V|={} |E|={}, {} dist queries",
        g.num_vertices(),
        g.num_edges(),
        pairs.len()
    );

    let run = |lanes: usize| -> ServeMetrics {
        match serve_workload(&g, &profile, lanes, &pairs) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("bench failed (k={lanes}): {e}");
                std::process::exit(1);
            }
        }
    };

    let seq = run(1);
    let batched = run(8);

    println!("== Serve throughput: batched k=8 vs sequential k=1 ==");
    println!("-- k=1 sequential --\n{}", seq.report());
    println!("-- k=8 batched --\n{}", batched.report());
    let speedup = if seq.qps() > 0.0 {
        batched.qps() / seq.qps()
    } else {
        0.0
    };
    let io_ratio = if batched.edge_items_read > 0 {
        seq.edge_items_read as f64 / batched.edge_items_read as f64
    } else {
        0.0
    };
    println!(
        "speedup            {speedup:.2}x queries/s  (edge-stream items amortised {io_ratio:.2}x)"
    );
    if let Some(path) = graphd::bench::bench_json_path() {
        let body = format!(
            "{{\"qps_k1\": {:.3}, \"qps_k8\": {:.3}, \"speedup\": {speedup:.3}, \
               \"edge_items_k1\": {}, \"edge_items_k8\": {}, \
               \"wire_bytes_k8\": {}, \"local_bytes_k8\": {}}}",
            seq.qps(),
            batched.qps(),
            seq.edge_items_read,
            batched.edge_items_read,
            batched.wire_bytes,
            batched.local_bytes,
        );
        graphd::bench::bench_json_merge(&path, "serve", &body).expect("bench json");
        eprintln!("merged {path} (section: serve)");
    }
    if speedup < 3.0 {
        eprintln!("FAIL: batched k=8 must be >= 3x sequential k=1 (got {speedup:.2}x)");
        std::process::exit(1);
    }
}
